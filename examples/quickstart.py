"""Quickstart: FastAttention as a drop-in attention op + a tiny model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fast_attention
from repro.core.tiling import plan_two_level_tiling
from repro.kernels.fastattn.kernel import fastattn_fwd
from repro.kernels.fastattn.ref import standard_attention

# --- 1. the operator -------------------------------------------------------
rng = np.random.default_rng(0)
B, S, H, D = 2, 1024, 8, 64
q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)

out = fast_attention(q, k, v, causal=True, impl="reference")
print("fast_attention:", out.shape, out.dtype)

# --- 2. the Pallas kernel (interpret mode validates on CPU; on TPU pass
#        impl='pallas') -----------------------------------------------------
plan = plan_two_level_tiling(S, S, D)
print(f"two-level tiling plan: {plan}")
out_kernel = fastattn_fwd(
    q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
    v.transpose(0, 2, 1, 3), causal=True,
    block_q=plan.block_q, block_kv1=min(plan.block_kv1, S),
    block_kv2=plan.block_kv2, interpret=True)
ref = standard_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True)
print("kernel max err vs naive:",
      float(jnp.max(jnp.abs(out_kernel - ref))))

# --- 3. a model from the registry ------------------------------------------
from repro.config import ParallelConfig, get_model_config, reduce_for_smoke
from repro.models import build_model

cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
model = build_model(cfg, ParallelConfig(remat="none"))
params = model.init(jax.random.PRNGKey(0))
tokens = jnp.zeros((1, 16), jnp.int32)
logits = model.apply(params, tokens)
print("gemma2 (reduced) logits:", logits.shape)
