"""Distributed training driver: ~100M-param xLSTM for a few hundred steps
with checkpoint/resume, straggler monitoring, and (optionally) a small
multi-device mesh.

Default runs a fast reduced config; pass --full-100m for the real
xlstm-125m backbone at short sequence length (CPU: slow but functional).

    PYTHONPATH=src python examples/distributed_training.py [--steps 200]
"""
import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    argv = ["--arch", "xlstm-125m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-every", "20",
            "--ckpt-dir", "/tmp/repro_example_ckpt"]
    if not args.full_100m:
        argv.append("--smoke")

    print("phase 1: train from scratch")
    train_driver.main(argv)

    print("\nphase 2: resume from the latest checkpoint (+20 steps)")
    argv2 = list(argv)
    argv2[3] = str(args.steps + 20)
    train_driver.main(argv2 + ["--resume"])


if __name__ == "__main__":
    main()
