"""Long-context serving with the T4 CPU-host cooperative offload plan.

Shows: the offload planner deciding L_GPU/L_CPU for ultra-long prompts,
the host KV engine in action, generation through the serving engine, and
the page-pressure manager serving a long prompt on a deliberately
undersized page pool by swapping preempted KV to the host page pool.

    PYTHONPATH=src python examples/long_context_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (ParallelConfig, ServeConfig, get_model_config,
                          reduce_for_smoke)
from repro.core.offload import (HostOffloadEngine, OffloadLatencyModel,
                                max_context_length, plan_offload,
                                table3_row)
from repro.models import build_model
from repro.serving.engine import ServeEngine

# --- 1. plan: PanGu-38B on 8x 16GB devices (the paper's Table 3 setup) ----
cfg = get_model_config("pangu-38b")
print("== T4 offload plan sweep (PanGu-38B, 8 devices, 16 GB each) ==")
for s in (16_384, 65_536, 262_144):
    plan = plan_offload(cfg, batch=1, seq_len=s, gen_len=64, n_devices=8,
                        device_memory_gb=16)
    print(f"S={s:>7}: {plan.summary()}")

r = table3_row(cfg, 262_144, device_memory_gb=16)
print(f"\n256K decode attention / layer: classical="
      f"{r['classical_total_s'] * 1e3:.1f}ms  cooperative="
      f"{r['coop_total_s'] * 1e3:.1f}ms  speedup={r['speedup']:.2f}x")
mc = max_context_length(cfg, batch=1, n_devices=8, device_memory_gb=16,
                        host_memory_gb=768)
print(f"max context: device-only={mc['device_only']:,} -> "
      f"cooperative={mc['cooperative']:,}")

# --- 2. the host engine end to end (reduced model, real data path) --------
print("\n== host KV engine (reduced whisper dims) ==")
small = get_model_config("whisper-small")
plan = plan_offload(small, batch=1, seq_len=1024, gen_len=8, n_devices=1,
                    device_memory_gb=0.001)   # force offload
eng = HostOffloadEngine(small, plan, max_batch=1, max_seq=1024)
rng = np.random.default_rng(0)
k = jnp.asarray(rng.normal(size=(1, 512, small.num_kv_heads,
                                 small.head_dim)), jnp.float32)
eng.prefill_offload(0, k, k)
q = jnp.asarray(rng.normal(size=(1, 1, small.num_heads, small.head_dim)),
                jnp.float32)
out = eng.decode_attention(0, q, kv_len=[512])
print("host attention out:", out.shape, "l_cpu layers:", plan.l_cpu)

# --- 3. generation through the engine --------------------------------------
print("\n== generation (reduced hymba: SSM+SWA handles long context) ==")
cfg = reduce_for_smoke(get_model_config("hymba-1.5b"))
model = build_model(cfg, ParallelConfig(remat="none"))
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model=model, params=params, cfg=cfg,
                     serve=ServeConfig(max_seq_len=96, top_k=1))
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                            cfg.vocab_size)
tokens = engine.generate(prompt, 16)
print("generated:", tokens.shape, tokens[0].tolist())

# --- 4. long prompt on an undersized page pool: KV swap-to-host -------------
# The pool holds 9 usable pages x 16 tokens = 144 cache tokens, but the
# traffic worst-cases 3 x 96 = 288: under worst-case-reservation
# admission the long request would just queue behind the short ones.
# Optimistic admission runs them together; when the pool runs dry the
# newest sequence's KV pages are swapped to the host page pool and
# copied back when space frees up -- same tokens, ~half the device KV.
# Requests carry their own (greedy) SamplingParams -- the supported
# per-request path; the engine-global top_k/temperature knobs are
# deprecated defaults.
print("\n== page pressure: long prompt on an undersized pool (swap) ==")
from repro.serving.scheduler import Request, SamplingParams  # noqa: E402

cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
model = build_model(cfg, ParallelConfig(remat="none"))
params = model.init(jax.random.PRNGKey(0))
serve = ServeConfig(max_batch=3, max_seq_len=96,
                    page_size=16, num_pages=10,
                    preempt_policy="swap", debug_invariants=True)
engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)
rng = np.random.default_rng(0)
reqs = [Request(id=0, prompt=rng.integers(0, cfg.vocab_size, size=72),
                sampling=SamplingParams(max_new_tokens=24)),  # 96-tok worst
        Request(id=1, prompt=rng.integers(0, cfg.vocab_size, size=8),
                sampling=SamplingParams(max_new_tokens=64)),
        Request(id=2, prompt=rng.integers(0, cfg.vocab_size, size=6),
                sampling=SamplingParams(max_new_tokens=80))]
for ev in engine.generate_stream(reqs):
    if ev.finished:
        print(f"req {ev.request_id}: {len(reqs[ev.request_id].generated)} "
              f"tokens done (preempted "
              f"{reqs[ev.request_id].preemptions}x)")
mgr, pressure = engine.last_cache, engine.last_pressure
print(f"pool: peak {mgr.peak_used_pages}/{mgr.usable_pages} pages "
      f"({mgr.peak_utilization:.0%}); "
      f"{pressure.stats['preemptions']} preemptions, "
      f"{pressure.stats['swaps']} swaps "
      f"({pressure.stats['swap_bytes_out'] / 1024:.0f} KiB to host, "
      f"host-pool peak {pressure.host_pool.peak_pages} pages), "
      f"{pressure.stats['recomputes']} recomputes")
