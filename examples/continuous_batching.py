"""Continuous-batching serving: paged KV cache, multi-tenant decode,
chunked prefill, prefix sharing, and the persistent EngineCore.

Six requests with different prompt and generation lengths share three
decode slots and one page pool.  Tokens stream out per request the moment
they exist; finished sequences retire individually and their pages are
recycled into the next admission -- no sequence ever waits for the batch.
The last request carries a long prompt: it prefills in fixed 16-token
chunks under a per-step token budget, so watch the other sequences keep
streaming tokens while it works through its prompt (Sarathi-style
prefill/decode interleaving).

The second section turns on the radix-tree prefix cache
(``ServeConfig(prefix_cache=True)``): requests sharing a long system
prompt reuse its cached KV pages copy-on-write instead of recomputing
them -- warm requests prefill only ``prompt_len - matched_len`` tokens.

The third section drives the ``EngineCore`` step API directly --
``add_request`` (per-request SamplingParams, greedy and seeded
sampling), ``step``, ``abort`` mid-flight -- which is what
``generate_stream`` is a compatibility wrapper around.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import dataclasses

import jax
import numpy as np

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.models import build_model
from repro.serving.core import EngineCore
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, SamplingParams

# --- a tiny model (CPU smoke shapes; swap for a real config on TPU) --------
cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
model = build_model(cfg, ParallelConfig(remat="none"))
params = model.init(jax.random.PRNGKey(0))

# --- serving config: 3 slots, 16-token pages, pool of 16 usable pages ------
# (= 256 cache tokens -- *less* than 3 slots x 96 max_seq_len = a dense
# cache could not even be allocated this small).  Prefill runs in
# 16-token chunks, at most one chunk per engine step.
serve = ServeConfig(max_batch=3, max_seq_len=96, top_k=1,
                    page_size=16, num_pages=17,
                    prefill_chunk=16, prefill_token_budget=16)
engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)

# --- mixed-length traffic (last request: a long prompt) ---------------------
rng = np.random.default_rng(0)
spec = [(5, 6), (9, 3), (3, 10), (7, 4), (12, 5), (60, 4)]
requests = [Request(id=i, prompt=rng.integers(0, cfg.vocab_size, size=s),
                    max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]

print(f"pool: {serve.num_pages - 1} usable pages x {serve.page_size} tok, "
      f"{serve.max_batch} decode slots, {len(requests)} requests queued; "
      f"req 5 prefills {spec[-1][0]} tokens in "
      f"{serve.prefill_chunk_tokens}-token chunks")
for ev in engine.generate_stream(requests):
    mark = " <- finished" if ev.finished else ""
    print(f"req {ev.request_id}  token[{ev.index}] = {ev.token}{mark}")

mgr = engine.last_cache
print(f"\ndrained: {len(engine.last_scheduler.finished)} finished, "
      f"peak {mgr.peak_used_pages}/{mgr.num_pages - 1} pages, "
      f"{mgr.used_pages} still allocated")

# --- prefix sharing: one system prompt, many requests -----------------------
# A fresh engine with the radix prefix cache on.  The first wave prefills
# the 48-token system prompt cold and publishes its pages at retire; the
# second wave matches them (page-aligned: 48 = 3 full pages) and only
# computes its unique tail -- same greedy tokens, a fraction of the work.
print("\n--- prefix sharing (shared system prompt) ---")
serve2 = ServeConfig(max_batch=3, max_seq_len=96, top_k=1,
                     page_size=16, prefill_chunk=16, prefix_cache=True)
engine2 = ServeEngine(model=model, params=params, cfg=cfg, serve=serve2)
sys_prompt = rng.integers(0, cfg.vocab_size, size=48)


def wave(ids, seed):
    r = np.random.default_rng(seed)
    return [Request(id=i, prompt=np.concatenate(
        [sys_prompt, r.integers(0, cfg.vocab_size, size=5 + i % 3)]),
        max_new_tokens=4) for i in ids]


for name, requests in (("cold", wave(range(3), seed=1)),
                       ("warm", wave(range(3, 6), seed=2))):
    for ev in engine2.generate_stream(requests):
        pass                                   # tokens stream as before
    for r in requests:
        computed = len(r.prompt) - r.matched_len
        print(f"{name} req {r.id}: prompt {len(r.prompt)} tok, "
              f"matched {r.matched_len} cached, prefilled {computed}")
        if name == "warm":
            # every warm request shares the whole aligned system prompt:
            # prefill work == prompt_len - matched_len
            assert r.matched_len >= 48, r.matched_len

prefix = engine2.last_prefix
print(f"radix index: {prefix.cached_pages} pages cached, "
      f"stats {prefix.stats}")

# --- the step API: persistent core, mixed sampling, mid-flight abort --------
# The engine above is a thin wrapper around this.  Requests arrive while
# the engine runs (a frontend would do this from its accept loop), each
# with its own SamplingParams -- the seeded request's tokens come from a
# counter-based RNG stream, so they would be identical in any batch mix.
print("\n--- EngineCore: add_request / step / abort ---")
core = EngineCore(model, params, cfg,
                  ServeConfig(max_batch=3, max_seq_len=96, page_size=16,
                              prefill_chunk=16))
greedy = SamplingParams(max_new_tokens=6)                   # temperature 0
sampled = SamplingParams(temperature=0.8, top_k=8, seed=42,
                         max_new_tokens=6)
ids = [core.add_request(rng.integers(0, cfg.vocab_size, size=5), greedy),
       core.add_request(rng.integers(0, cfg.vocab_size, size=60), greedy),
       core.add_request(rng.integers(0, cfg.vocab_size, size=7), sampled)]
for _ in range(2):
    for ev in core.step():
        print(f"  step {core.steps}: req {ev.request_id} "
              f"token[{ev.index}] = {ev.token}")
# the long prompt is still chunk-prefilling -- abort it mid-flight: its
# pages return to the pool, nothing leaks, everyone else keeps going
print(f"  abort req {ids[1]} (state "
      f"{core.get_request(ids[1]).state}) -> {core.abort(ids[1])}")
core.add_request(rng.integers(0, cfg.vocab_size, size=9),
                 SamplingParams(max_new_tokens=4))          # mid-flight add
while core.has_work:
    for ev in core.step():
        if ev.finished:
            print(f"  req {ev.request_id} finished "
                  f"({ev.index + 1} tokens)")
s = core.stats()
print(f"core: {s['steps']} steps, {s['events_emitted']} tokens, "
      f"{s['aborts']} aborted, {s['pages_used']} pages still used")

# --- speculative decoding: prompt-lookup drafting + one-launch verify -------
# The drafter guesses the next K tokens from the request's own text (no
# second model), the engine scores all K+1 positions in a single paged-
# prefill launch and keeps the longest valid prefix.  Greedy output is
# bit-identical to plain decode -- speculation only changes how many
# engine steps the same tokens take.
print("\n--- speculative decoding (prompt-lookup) ---")
motif = rng.integers(1, cfg.vocab_size, size=6).tolist()
rep_prompt = np.array(motif * 5, np.int32)        # repetitive: drafts land


def drain(serve_cfg):
    c = EngineCore(model, params, cfg, serve_cfg)
    c.add_request(rep_prompt, SamplingParams(max_new_tokens=12))
    toks = []
    while c.has_work:
        toks += [ev.token for ev in c.step() if ev.kind == "token"]
    return toks, c


base = ServeConfig(max_batch=3, max_seq_len=96, page_size=16,
                   prefill_chunk=16)
plain_toks, plain = drain(base)
spec_toks, spec = drain(dataclasses.replace(base, spec_mode="lookup",
                                            spec_tokens=4))
sp = spec.stats()["spec"]
print(f"  tokens identical: {spec_toks == plain_toks}, steps "
      f"{plain.stats()['steps']} -> {spec.stats()['steps']}, "
      f"accept rate {sp['accept_rate']:.0%} "
      f"({sp['accepted']}/{sp['drafted']} drafts over "
      f"{sp['verify_launches']} verify launches)")
assert spec_toks == plain_toks
