"""Fault tolerance: error taxonomy, deterministic injection, chaos soak.

Unit level: ``FaultInjector`` schedules (nth/every/prob/burst/times) are
deterministic under a fixed seed and reject unknown sites.  System
level: a no-op injector adds zero overhead sites to a traced step
(trace-count + bit-identity assertion); injected swap D2H/H2D failures
retry then downgrade to recompute without failing the request; per-
request faults (page_alloc, cow_copy, sample, non-finite logits)
quarantine exactly the offending request while survivors' greedy tokens
stay bit-identical to a fault-free oracle; deadlines shed waiting and
abort running requests; the bounded waiting queue rejects or sheds; and
the chaos soak drives every named site at once across a mixed
prefill/decode/preemption/prefix-sharing workload with invariants
checked every step and zero leaked pages/stashes at the end.
"""
import jax
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.core import EngineCore
from repro.serving.faults import (SITES, EngineError, FaultInjector,
                                  InjectedFault, LogitError, RequestError,
                                  RequestRejected, RequestTimeout)
from repro.serving.scheduler import FAILED, FINISHED, RUNNING, SamplingParams


# ---------------------------------------------------------------------------
# unit: the injector itself (the chaos harness must be trustworthy)
# ---------------------------------------------------------------------------

def test_injector_schedules_fire_exactly_as_specified():
    inj = FaultInjector(seed=0)
    inj.arm("page_alloc", nth=(3, 9))
    inj.arm("swap_d2h", every=4)
    inj.arm("decode_launch", burst=(5, 2))
    inj.arm("sample", every=2, times=2)

    def calls(site, n):
        fired = []
        for i in range(1, n + 1):
            try:
                inj.fire(site)
            except InjectedFault as e:
                assert e.site == site and e.call == i
                fired.append(i)
        return fired

    assert calls("page_alloc", 12) == [3, 9]
    assert calls("swap_d2h", 12) == [4, 8, 12]
    assert calls("decode_launch", 8) == [5, 6]
    assert calls("sample", 10) == [2, 4]          # times=2 caps total fires
    assert calls("swap_h2d", 5) == []             # un-armed site never fires
    assert inj.total_fired == 9
    assert inj.calls("page_alloc") == 12
    assert inj.stats()["fired"] == 9


def test_injector_probability_deterministic_under_seed():
    def run(seed):
        inj = FaultInjector(seed=seed).arm("sample", prob=0.3) \
            .arm("swap_d2h", prob=0.3)
        for _ in range(200):
            for site in ("sample", "swap_d2h"):
                try:
                    inj.fire(site)
                except InjectedFault:
                    pass
        return inj.fired_log

    a, b, c = run(7), run(7), run(8)
    assert a == b, "same seed must replay the same fire pattern"
    assert len(a) > 0
    assert a != c, "different seeds should draw different patterns"
    # distinct sites under one seed draw independent streams
    assert [n for s, n in a if s == "sample"] != \
        [n for s, n in a if s == "swap_d2h"]


def test_injector_validates_sites_and_schedules():
    inj = FaultInjector()
    with pytest.raises(ValueError, match="unknown fault site"):
        inj.arm("warp_core")
    with pytest.raises(ValueError, match="unknown fault site"):
        inj.fire("warp_core")
    with pytest.raises(ValueError, match="bad schedule"):
        inj.arm("sample", prob=1.5)
    with pytest.raises(ValueError, match="burst"):
        inj.arm("sample", burst=(0, 1))
    assert set(SITES) == {"page_alloc", "swap_d2h", "swap_h2d", "cow_copy",
                          "prefill_launch", "decode_launch", "sample",
                          "spec_verify"}


def test_error_taxonomy_shapes():
    e = RequestRejected("no room", request_id=4)
    assert isinstance(e, RequestError) and isinstance(e, ValueError)
    assert e.detail == "rejected: no room" and e.request_id == 4
    assert RequestTimeout("late").code == "timeout"
    assert LogitError("nan").code == "logits"
    assert issubclass(EngineError, RuntimeError)
    assert not issubclass(EngineError, RequestError)


# ---------------------------------------------------------------------------
# system fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    from repro.models import build_model
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _core(built, *, injector=None, detokenize=None, clock=None, **serve_kw):
    model, params, cfg = built
    serve_kw.setdefault("max_batch", 3)
    serve_kw.setdefault("max_seq_len", 96)
    serve_kw.setdefault("page_size", 16)
    serve_kw.setdefault("prefill_chunk", 16)
    serve_kw.setdefault("debug_invariants", True)
    return EngineCore(model, params, cfg, ServeConfig(**serve_kw),
                      injector=injector, detokenize=detokenize,
                      clock=clock), cfg


def _collect(events, toks, errs):
    for ev in events:
        if ev.kind == "token":
            toks.setdefault(ev.request_id, []).append(ev.token)
        elif ev.kind == "error":
            errs.append(ev)


def _drain(core, toks=None, errs=None, max_steps=2000):
    """step() until idle; returns (token events by id, error events).
    Pass toks/errs to continue accumulating over earlier manual steps."""
    toks = {} if toks is None else toks
    errs = [] if errs is None else errs
    steps = 0
    while core.has_work:
        steps += 1
        assert steps <= max_steps, "engine failed to drain"
        _collect(core.step(), toks, errs)
    return toks, errs


def _oracle(built, specs, **serve_kw):
    """Fault-free greedy tokens per request id for the given specs
    (id -> prompt): greedy decode is batch-composition invariant, so
    this oracle is valid whatever faults reshuffle the chaos batch."""
    core, _ = _core(built, **serve_kw)
    for rid, (prompt, n) in specs.items():
        core.add_request(prompt, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(core)
    assert not errs
    return toks


# ---------------------------------------------------------------------------
# system: zero-overhead / trace-neutral no-op injector
# ---------------------------------------------------------------------------

def test_noop_injector_is_trace_neutral_and_bit_identical(built):
    _, _, cfg = built
    rng = np.random.default_rng(20)
    specs = {i: (rng.integers(0, cfg.vocab_size, size=s), 5)
             for i, s in enumerate((5, 40, 9))}

    bare, _ = _core(built, num_pages=13)
    for rid, (p, n) in specs.items():
        bare.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    plain, plain_errs = _drain(bare)
    assert not plain_errs

    inj = FaultInjector(seed=0)                   # constructed, never armed
    wired, _ = _core(built, injector=inj, num_pages=13)
    for rid, (p, n) in specs.items():
        wired.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(wired)
    assert toks == plain and not errs
    # the unarmed injector saw every host-side site but traced nothing
    # extra: launch counts and trace counts match the injector-less run
    assert wired.prefill_trace_count == bare.prefill_trace_count
    assert wired.prefill_launches == bare.prefill_launches
    assert wired.steps == bare.steps
    assert inj.total_fired == 0
    for site in ("page_alloc", "prefill_launch", "decode_launch", "sample"):
        assert inj.calls(site) > 0, f"site {site} never threaded"


# ---------------------------------------------------------------------------
# system: swap fault retry + downgrade (never fails the request)
# ---------------------------------------------------------------------------

def _pressure_specs(cfg, rng):
    return {0: (rng.integers(0, cfg.vocab_size, size=8), 60),
            1: (rng.integers(0, cfg.vocab_size, size=8), 60)}


def test_swap_d2h_fault_downgrades_to_recompute(built):
    """Every swap-out DMA fails: after the retry budget the victim is
    preempted by recompute instead -- zero failed requests, tokens
    bit-identical to the fault-free run."""
    _, _, cfg = built
    rng = np.random.default_rng(21)
    specs = _pressure_specs(cfg, rng)
    kw = dict(num_pages=7, preempt_policy="swap", max_batch=2)
    want = _oracle(built, specs, **kw)

    inj = FaultInjector(seed=1).arm("swap_d2h", every=1)
    core, _ = _core(built, injector=inj, **kw)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(core)
    st = core.pressure.stats
    assert st["preemptions"] > 0 and st["swaps"] == 0
    assert st["swap_fail_downgrades"] > 0
    # each downgrade burned the full retry budget (swap_retries+1 tries)
    assert st["swap_retries"] == \
        st["swap_fail_downgrades"] * (core.serve.swap_retries + 1)
    assert not errs and core.stats()["health"]["failed"] == 0
    assert toks == want
    assert core.mgr.used_pages == 0
    assert len(core.pressure.host_pool) == 0


def test_swap_h2d_fault_downgrades_restore_to_recompute(built):
    """Mid-step swap-in failure: the stash survives the failed scatter
    (peek-then-pop), the resume unwinds and downgrades to recompute
    after the retry budget -- request never fails, tokens identical."""
    _, _, cfg = built
    rng = np.random.default_rng(22)
    specs = _pressure_specs(cfg, rng)
    kw = dict(num_pages=7, preempt_policy="swap", max_batch=2)
    want = _oracle(built, specs, **kw)

    inj = FaultInjector(seed=2).arm("swap_h2d", every=1)
    core, _ = _core(built, injector=inj, **kw)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(core)
    st = core.pressure.stats
    assert st["swaps"] > 0, "no swap-out: the h2d site was never reached"
    assert st["swap_fail_downgrades"] > 0 and st["swap_drops"] > 0
    assert st["swap_retries"] >= core.serve.swap_retries + 1
    assert not errs and core.stats()["health"]["failed"] == 0
    assert toks == want
    assert core.mgr.used_pages == 0
    assert len(core.pressure.host_pool) == 0, "stash leaked or lost"


def test_transient_swap_fault_retries_through(built):
    """A fault budget smaller than the retry budget: the nth-call D2H
    faults are absorbed by retries, swaps still happen, nothing is
    downgraded or failed."""
    _, _, cfg = built
    rng = np.random.default_rng(23)
    specs = _pressure_specs(cfg, rng)
    kw = dict(num_pages=7, preempt_policy="swap", max_batch=2)
    want = _oracle(built, specs, **kw)

    inj = FaultInjector(seed=3).arm("swap_d2h", nth=(1,))
    core, _ = _core(built, injector=inj, **kw)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(core)
    st = core.pressure.stats
    assert st["swap_retries"] == 1 and st["swap_fail_downgrades"] == 0
    assert st["swaps"] > 0
    assert not errs and toks == want


# ---------------------------------------------------------------------------
# system: per-request quarantine (isolation)
# ---------------------------------------------------------------------------

def test_sample_fault_quarantines_one_request_only(built):
    """An injected sampling fault fails exactly the request being
    sampled; the co-tenant's greedy tokens are bit-identical to its solo
    run and no pages or stashes leak."""
    _, _, cfg = built
    rng = np.random.default_rng(24)
    specs = {0: (rng.integers(0, cfg.vocab_size, size=5), 6),
             1: (rng.integers(0, cfg.vocab_size, size=9), 6)}
    want = _oracle(built, specs, num_pages=13)

    inj = FaultInjector(seed=4).arm("sample", nth=(1,))
    core, _ = _core(built, injector=inj, num_pages=13)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    r0, r1 = core.requests[0], core.requests[1]
    toks, errs = _drain(core)
    # the first sample call belongs to request 0 (first admitted slot)
    assert r0.state == FAILED and r0.error.startswith("injected")
    assert len(errs) == 1 and errs[0].request_id == 0
    assert errs[0].finished and errs[0].kind == "error"
    assert 0 not in toks
    assert r1.state == FINISHED and toks[1] == want[1]
    st = core.stats()
    assert st["health"]["failed"] == 1
    assert st["health"]["last_error"].startswith("request 0")
    assert core.mgr.used_pages == 0
    core.mgr.check_invariants()


def test_page_alloc_fault_quarantines_grower(built):
    """page_alloc fires pre-mutation inside append: the growing request
    is quarantined with its pages freed; the survivor is untouched."""
    _, _, cfg = built
    rng = np.random.default_rng(25)
    specs = {0: (rng.integers(0, cfg.vocab_size, size=20), 8),
             1: (rng.integers(0, cfg.vocab_size, size=9), 8)}
    want = _oracle(built, specs, num_pages=13)

    inj = FaultInjector(seed=5).arm("page_alloc", nth=(2,))
    core, _ = _core(built, injector=inj, num_pages=13)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(core)
    assert len(errs) == 1
    failed = errs[0].request_id
    survivor = 1 - failed
    assert toks[survivor] == want[survivor]
    assert core.stats()["health"]["failed"] == 1
    assert core.mgr.used_pages == 0
    core.mgr.check_invariants()


def test_launch_faults_only_delay_never_fail(built):
    """prefill_launch / decode_launch faults fire before any page
    mutation: the work simply retries next step -- more steps, same
    tokens, zero failures."""
    _, _, cfg = built
    rng = np.random.default_rng(26)
    specs = {i: (rng.integers(0, cfg.vocab_size, size=s), 6)
             for i, s in enumerate((5, 40, 9))}
    want = _oracle(built, specs, num_pages=13)
    base, _ = _core(built, num_pages=13)
    for rid, (p, n) in specs.items():
        base.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    _drain(base)

    inj = FaultInjector(seed=6).arm("prefill_launch", every=3) \
        .arm("decode_launch", every=4)
    core, _ = _core(built, injector=inj, num_pages=13)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    toks, errs = _drain(core)
    assert not errs and toks == want
    assert core.stats()["health"]["failed"] == 0
    assert inj.total_fired > 0
    assert core.steps > base.steps, "skipped launches must cost steps"


def _poison_slot0_decode(core):
    """Wrap the core's cached fused decode fn so slot 0's logits row is
    always NaN -- a per-slot numerical blow-up, without touching any
    other slot's row.  (Poisoning an embedding row would NOT do: the
    smoke model ties embeddings, so a NaN embed row NaNs one logit
    *column* for every co-batched request.)"""
    import jax.numpy as jnp
    pre_scan, pre_chunk, dec, verify = core._paged_fns()

    def poisoned_dec(params, tok, pools, table, pos):
        logits, pools = dec(params, tok, pools, table, pos)
        return logits.at[0].set(jnp.nan), pools

    core._paged_fn_cache[(core._paged_impl(), core.tp_plan)] = (
        pre_scan, pre_chunk, poisoned_dec, verify)


def test_logit_guard_fails_only_the_nan_request(built):
    """A numerical blow-up confined to one slot's logits row: under
    logit_guard="fail" only that request fails (structured "logits"
    error); the clean co-tenant matches its oracle.  Under "ignore" the
    NaN request survives (garbage tokens, contained)."""
    _, _, cfg = built
    rng = np.random.default_rng(27)
    specs = {0: (rng.integers(0, cfg.vocab_size, size=5), 5),
             1: (rng.integers(0, cfg.vocab_size, size=7), 5)}
    want = _oracle(built, specs, num_pages=13)

    core, _ = _core(built, num_pages=13)
    _poison_slot0_decode(core)
    for rid, (p, n) in specs.items():
        core.add_request(p, SamplingParams(max_new_tokens=n),
                         request_id=rid)
    r0 = core.requests[0]
    toks, errs = _drain(core)
    # request 0 (slot 0) got its clean prefill-sampled first token, then
    # died on its first NaN decode row; request 1 never noticed
    assert r0.state == FAILED and "logits" in r0.error
    assert len(errs) == 1 and errs[0].request_id == 0
    assert errs[0].detail.startswith("logits")
    assert toks[0] == want[0][:1]
    assert toks[1] == want[1]
    assert core.stats()["health"]["failed"] == 1
    assert core.mgr.used_pages == 0
    core.mgr.check_invariants()

    ignore, _ = _core(built, num_pages=13, logit_guard="ignore")
    _poison_slot0_decode(ignore)
    for rid, (p, n) in specs.items():
        ignore.add_request(p, SamplingParams(max_new_tokens=n),
                           request_id=rid)
    toks, errs = _drain(ignore)
    assert not errs and len(toks[0]) == 5
    assert toks[1] == want[1]


# ---------------------------------------------------------------------------
# system: deadlines & load shedding
# ---------------------------------------------------------------------------

def test_deadline_sheds_waiting_request(built):
    _, _, cfg = built
    rng = np.random.default_rng(28)
    clk = [0.0]
    core, _ = _core(built, clock=lambda: clk[0], max_batch=1)
    core.add_request(rng.integers(0, cfg.vocab_size, size=6),
                     SamplingParams(max_new_tokens=8), request_id=0)
    core.add_request(rng.integers(0, cfg.vocab_size, size=6),
                     SamplingParams(max_new_tokens=8, deadline_ms=50.0),
                     request_id=1)
    toks, errs = {}, []
    _collect(core.step(), toks, errs)             # 0 admitted, 1 waits
    clk[0] += 1.0                                 # 1000ms >> 50ms
    late = core.requests[1]
    _drain(core, toks, errs)
    assert late.state == FAILED and late.error.startswith("timeout")
    assert len(errs) == 1 and errs[0].request_id == 1
    assert len(toks[0]) == 8                      # no-deadline req unharmed
    assert core.stats()["health"]["timed_out"] == 1
    assert core.mgr.used_pages == 0


def test_deadline_aborts_running_request_cleanly(built):
    _, _, cfg = built
    rng = np.random.default_rng(29)
    clk = [0.0]
    core, _ = _core(built, clock=lambda: clk[0])
    core.add_request(rng.integers(0, cfg.vocab_size, size=6),
                     SamplingParams(max_new_tokens=40, deadline_ms=100.0),
                     request_id=0)
    core.add_request(rng.integers(0, cfg.vocab_size, size=6),
                     SamplingParams(max_new_tokens=6), request_id=1)
    toks, errs = {}, []
    while core.requests[0].state != RUNNING:
        _collect(core.step(), toks, errs)
    _collect(core.step(), toks, errs)             # a decode token or two
    assert core.requests[0].generated, "request 0 never decoded"
    clk[0] += 1.0
    doomed = core.requests[0]
    _drain(core, toks, errs)
    assert doomed.state == FAILED and doomed.error.startswith("timeout")
    assert [e.request_id for e in errs] == [0]
    assert len(toks[1]) == 6
    assert core.stats()["health"]["timed_out"] == 1
    assert core.mgr.used_pages == 0
    core.mgr.check_invariants()


def test_deadline_ms_validation():
    with pytest.raises(ValueError, match="deadline_ms"):
        SamplingParams(deadline_ms=0.0)
    with pytest.raises(ValueError, match="deadline_ms"):
        SamplingParams(deadline_ms=-5.0)


def test_bounded_queue_reject_policy(built):
    _, _, cfg = built
    rng = np.random.default_rng(30)
    core, _ = _core(built, max_waiting=1, queue_policy="reject")
    core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                     SamplingParams(max_new_tokens=3), request_id=0)
    with pytest.raises(RequestRejected, match="queue full"):
        core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                         SamplingParams(max_new_tokens=3), request_id=1)
    assert 1 not in core.requests
    toks, errs = _drain(core)
    assert not errs and len(toks[0]) == 3


def test_bounded_queue_shed_oldest_policy(built):
    _, _, cfg = built
    rng = np.random.default_rng(31)
    core, _ = _core(built, max_waiting=1, queue_policy="shed_oldest")
    core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                     SamplingParams(max_new_tokens=3), request_id=0)
    old = core.requests[0]
    core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                     SamplingParams(max_new_tokens=3), request_id=1)
    assert old.state == FAILED and old.error.startswith("rejected")
    assert core.stats()["health"]["shed"] == 1
    toks, errs = _drain(core)
    # the shed victim's structured error event surfaces on the next step
    assert [e.request_id for e in errs] == [0]
    assert 0 not in toks and len(toks[1]) == 3


# ---------------------------------------------------------------------------
# system: the chaos soak (the acceptance scenario)
# ---------------------------------------------------------------------------

def test_chaos_soak_all_sites(built):
    """Seeded random injection at every named site over a mixed
    prefill/decode/preemption/prefix-sharing workload with mid-flight
    arrivals and an abort: invariants (refcount balance, no leaks, no
    orphaned stashes, no stale COW debt) hold every step, and every
    surviving request's greedy tokens are bit-identical to the
    fault-free oracle."""
    _, _, cfg = built
    rng = np.random.default_rng(32)
    shared = rng.integers(0, cfg.vocab_size, size=32)   # 2 shared pages

    def prompt(extra):
        return np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, size=extra)])

    specs = {0: (prompt(5), 8), 1: (prompt(9), 8),
             2: (rng.integers(0, cfg.vocab_size, size=40), 8),
             3: (prompt(3), 10), 4: (rng.integers(0, cfg.vocab_size,
                                                  size=7), 10),
             5: (prompt(6), 6), 6: (rng.integers(0, cfg.vocab_size,
                                                 size=12), 6)}
    kw = dict(num_pages=15, preempt_policy="swap", max_batch=3,
              prefix_cache=True)
    want = _oracle(built, specs, **kw)

    inj = FaultInjector(seed=1234)
    for site in SITES:
        inj.arm(site, prob=0.05)
    core, _ = _core(built, injector=inj, **kw)
    late = {3, 4, 5, 6}
    for rid in sorted(set(specs) - late):
        core.add_request(specs[rid][0],
                         SamplingParams(max_new_tokens=specs[rid][1]),
                         request_id=rid)
    toks, errs = {}, []
    steps = 0
    aborted_mid = False
    while core.has_work:
        steps += 1
        assert steps <= 3000, "chaos soak failed to drain"
        if steps == 3:
            for rid in sorted(late):
                core.add_request(specs[rid][0], SamplingParams(
                    max_new_tokens=specs[rid][1]), request_id=rid)
        if steps == 6 and 6 in core.requests and not aborted_mid:
            aborted_mid = core.abort(6)           # client disconnect
        for ev in core.step():
            if ev.kind == "token":
                toks.setdefault(ev.request_id, []).append(ev.token)
            elif ev.kind == "error":
                errs.append(ev)
        # the invariant gauntlet, every single step
        core.mgr.check_invariants(extern_refs=core.prefix.page_refs())
        assert core.pressure.host_pool.used_pages >= 0
    assert inj.total_fired > 0, "chaos run injected nothing"

    # terminal bookkeeping: no leaks anywhere
    assert core.mgr.used_pages == core.prefix.cached_pages
    assert len(core.pressure.host_pool) == 0, "orphaned swap stash"
    assert not core.mgr.cow_pending, "stale COW debt"
    # telemetry: every span a terminal transition should have closed
    # (finished, quarantined, shed, timed out AND aborted) actually is
    assert core.tracer.open_span_count() == 0, "leaked lifecycle spans"
    core.mgr.check_invariants(extern_refs=core.prefix.page_refs())

    # every request reached exactly one terminal state, and survivors
    # are bit-identical to the fault-free oracle
    finished = {r.id for r in core.sched.finished}
    failed = {e.request_id for e in errs}
    health = core.stats()["health"]
    assert health["failed"] + health["shed"] + health["timed_out"] == \
        len(failed)
    for rid in specs:
        if rid in finished:
            assert toks[rid] == want[rid], f"survivor {rid} diverged"
        else:
            assert rid in failed or (aborted_mid and rid == 6)
    assert finished, "no request survived the soak (probs too hot)"

    # deterministic: replaying the same seed reproduces the same run
    inj2 = FaultInjector(seed=1234)
    for site in SITES:
        inj2.arm(site, prob=0.05)
    core2, _ = _core(built, injector=inj2, **kw)
    for rid in sorted(set(specs) - late):
        core2.add_request(specs[rid][0], SamplingParams(
            max_new_tokens=specs[rid][1]), request_id=rid)
    toks2 = {}
    steps2 = 0
    while core2.has_work:
        steps2 += 1
        assert steps2 <= 3000
        if steps2 == 3:
            for rid in sorted(late):
                core2.add_request(specs[rid][0], SamplingParams(
                    max_new_tokens=specs[rid][1]), request_id=rid)
        if steps2 == 6 and 6 in core2.requests:
            core2.abort(6)
        for ev in core2.step():
            if ev.kind == "token":
                toks2.setdefault(ev.request_id, []).append(ev.token)
    assert inj2.fired_log == inj.fired_log
    assert toks2 == toks
