"""T4 CPU-GPU cooperative strategy: planner formulas + host engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import get_model_config
from repro.core.offload import (HostOffloadEngine, OffloadLatencyModel,
                                max_context_length, plan_offload, table3_row)


def test_planner_matches_paper_formula_mha():
    """For an MHA + 2-matrix-FFN model our M_w reduces to the paper's
    L(8 H1^2 + 4 H1 H2) (Eq. 17)."""
    cfg = get_model_config("pangu-38b")     # MHA, gelu MLP (2 matrices)
    p = plan_offload(cfg, batch=1, seq_len=16384, gen_len=64, n_devices=8,
                     device_memory_gb=16)
    h1, h2, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    paper_mw = L * (8 * h1 * h1 + 4 * h1 * h2)
    # within 5% (we add norms/bias-free terms the paper drops)
    assert abs(p.bytes_weights - paper_mw) / paper_mw < 0.05
    # Eq. 18: M_kv = 4 B H1 (S+O) / n
    assert p.bytes_kv_layer == pytest.approx(
        4 * 1 * h1 * (16384 + 64) / 8, rel=1e-6)


@settings(max_examples=50, deadline=None)
@given(seq=st.integers(1024, 1 << 19), mem=st.floats(8, 80))
def test_planner_invariants(seq, mem):
    cfg = get_model_config("pangu-38b")
    p = plan_offload(cfg, batch=1, seq_len=seq, gen_len=64, n_devices=8,
                     device_memory_gb=mem)
    assert 0 <= p.l_gpu <= cfg.num_layers
    assert p.l_gpu + p.l_cpu == cfg.num_layers
    if not p.needs_offload:
        assert p.l_cpu == 0


def test_max_context_extension():
    """The cooperative strategy must extend max context by >= 4x on a
    memory-tight node (the paper's 16K -> 256K claim shape)."""
    cfg = get_model_config("pangu-38b")
    r = max_context_length(cfg, batch=1, n_devices=8, device_memory_gb=16,
                           host_memory_gb=768)
    assert r["cooperative"] >= 4 * max(r["device_only"], 1)
    assert r["cooperative"] >= 256 * 1024 or r["device_only"] == 0


def test_table3_speedup_regime():
    """Cooperative beats classical offloading at long context (Table 3:
    1.27-1.48x) under the paper's PCIe/CPU constants."""
    cfg = get_model_config("pangu-38b")
    row = table3_row(cfg, 262144, device_memory_gb=16)
    assert row["offload"]
    assert row["speedup"] > 1.1
    # Off_Upload is tiny & ~constant (paper: fixed-dim results only)
    assert row["coop_offupload_s"] < 0.01 * row["coop_cpu_calc_s"] * 100


def test_host_engine_end_to_end():
    cfg = get_model_config("whisper-small")   # small dims, quick
    from repro.core.offload import OffloadPlan
    plan = OffloadPlan(l_gpu=1, l_cpu=1, bytes_weights=0, bytes_kv_layer=0,
                       bytes_mid=0, bytes_vocab=0, device_budget=0,
                       needs_offload=True)
    eng = HostOffloadEngine(cfg, plan, max_batch=2, max_seq=32)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(2, 8, cfg.num_kv_heads,
                                     cfg.head_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=k.shape), jnp.float32)
    eng.prefill_offload(0, k, v)
    q = jnp.asarray(rng.normal(size=(2, 1, cfg.num_heads, cfg.head_dim)),
                    jnp.float32)
    out = eng.decode_attention(0, q, kv_len=[8, 8])
    assert out.shape == (2, 1, cfg.num_heads, cfg.head_dim)
    # oracle: same attention computed directly
    from repro.kernels.fastattn.ref import decode_reference
    ref = decode_reference(q.transpose(0, 2, 1, 3),
                           jnp.pad(k, ((0, 0), (0, 24), (0, 0), (0, 0))
                                   ).transpose(0, 2, 1, 3),
                           jnp.pad(v, ((0, 0), (0, 24), (0, 0), (0, 0))
                                   ).transpose(0, 2, 1, 3),
                           jnp.asarray([8, 8])).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
