"""Pipeline parallelism: GPipe schedule == sequential stage application."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_gpipe_matches_sequential():
    code = """
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.training.pipeline import make_pipeline, bubble_fraction
mesh = make_mesh((4,), ('stage',))
rng = np.random.default_rng(0)
n_stages, n_micro, mb, d = 4, 8, 2, 16
# one linear+tanh layer per stage
ws = jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.5, jnp.float32)
x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

def stage_fn(w, x):
    return jnp.tanh(x @ w)

pipe = jax.jit(make_pipeline(mesh, stage_fn, params_spec=P('stage'),
                             x_spec=P()))
out = pipe(ws, x)
# sequential oracle
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ ws[s])
err = float(jnp.max(jnp.abs(out - ref)))
print(json.dumps({'err': err,
                  'bubble': bubble_fraction(n_stages, n_micro)}))
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(ROOT, "src"))
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    r = json.loads(res.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-5
    assert abs(r["bubble"] - 3 / 11) < 1e-9
