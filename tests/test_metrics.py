"""Engine telemetry: registry units, lifecycle spans, flight recorder.

Unit level: ``Counter``/``Gauge``/``Histogram`` windowed semantics
(cumulative totals survive a window reset, ``le``-inclusive bucket
edges exactly like Prometheus), registry kind validation, text
exposition format, and the flight-recorder ring + Chrome trace
rendering on synthetic records.  System level: the whole engine runs on
an injectable clock (a frozen clock yields exactly-zero durations
everywhere -- the regression test for stray ``time.perf_counter()``
calls); engine-native TTFT/TPOT from the lifecycle tracer agree
*exactly* with bench-side arithmetic under a manually stepped clock;
spans close under preemption/swap/abort/quarantine (zero open spans
after drain); a quarantine and a forced ``EngineError`` both dump the
flight recorder (the error carries it as ``.flight``) and the dump
renders as valid Chrome ``trace_event`` JSON; and telemetry is
trace-neutral: metrics on vs off changes neither trace counts nor
tokens.
"""
import json

import jax
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.core import EngineCore
from repro.serving.faults import EngineError, FaultInjector
from repro.serving.metrics import (DEFAULT_TIME_BUCKETS, Counter,
                                   FlightRecorder, Gauge, Histogram,
                                   MetricsRegistry)
from repro.serving.scheduler import SamplingParams


# ---------------------------------------------------------------------------
# unit: the registry primitives
# ---------------------------------------------------------------------------

def test_counter_window_vs_cumulative():
    c = Counter("x_total")
    c.inc()
    c.inc(4)
    assert c.value == 5 and c.window == 5
    c.reset_window()
    assert c.value == 5 and c.window == 0   # total is Prometheus-monotonic
    c.inc(2)
    assert c.value == 7 and c.window == 2
    assert c.snapshot() == {"type": "counter", "total": 7, "window": 2}


def test_gauge_last_value_vs_high_water():
    g = Gauge("pages")
    g.set(5)
    g.set(3)
    assert g.value == 3                      # plain gauge: last write wins
    hw = Gauge("peak", high_water=True)
    hw.set(5)
    hw.set(3)
    assert hw.value == 5                     # high water: window max
    hw.reset_window()
    assert hw.value == 0.0                   # re-arms
    g.reset_window()
    assert g.value == 3                      # plain gauge untouched


def test_histogram_bucket_edges_are_le_inclusive():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0))
    h.observe(1.0)            # == edge: lands in that edge's bucket
    h.observe(1.0 + 1e-9)     # just above: next bucket
    h.observe(4.0)
    h.observe(9.0)            # above the last edge: +Inf only
    snap = h.snapshot()
    assert snap["buckets"] == {1.0: 1, 2.0: 2, 4.0: 3}   # cumulative
    assert snap["count"] == 4 and snap["max"] == 9.0 and snap["min"] == 1.0
    assert h.total_count == 4
    # bucketed percentiles: smallest edge covering the quantile
    assert h.percentile(25) == 1.0
    assert h.percentile(75) == 4.0
    assert h.percentile(100) == 9.0          # window max beyond the edges
    h.reset_window()
    assert h.count == 0 and h.percentile(50) == 0.0
    assert h.total_count == 4                # cumulative survives
    with pytest.raises(ValueError, match="bucket"):
        Histogram("empty", buckets=())


def test_registry_get_or_create_and_kind_mismatch():
    r = MetricsRegistry()
    c = r.counter("a_total")
    assert r.counter("a_total") is c         # get-or-create: same object
    with pytest.raises(TypeError, match="a_total"):
        r.gauge("a_total")
    with pytest.raises(TypeError, match="Histogram"):
        r.histogram("a_total")
    r.observe("h", 0.5)
    assert "h" in r and isinstance(r["h"], Histogram)
    assert r.names() == ["a_total", "h"]


def test_registry_snapshot_reset_partitions_time():
    r = MetricsRegistry()
    r.inc("n_total", 3)
    r.observe("h", 0.2)
    first = r.snapshot(reset=True)           # atomically opens window 2
    assert first["n_total"]["window"] == 3
    assert first["h"]["count"] == 1
    r.inc("n_total", 2)
    second = r.snapshot()
    assert second["n_total"] == {"type": "counter", "total": 5, "window": 2}
    assert second["h"]["count"] == 0         # window 2 saw no observations
    assert json.loads(json.dumps(r.to_json()))  # JSON-safe by construction


def test_prometheus_exposition_format():
    r = MetricsRegistry()
    r.counter("req_total", help="requests").inc(3)
    r.gauge("pages").set(7)
    h = r.histogram("lat_seconds", buckets=(0.5, 1.0))
    h.observe(0.5)
    h.observe(2.0)
    r.reset_window()                          # totals must keep exposing
    h.observe(0.25)
    text = r.to_prometheus()
    lines = text.splitlines()
    assert "# HELP req_total requests" in lines
    assert "# TYPE req_total counter" in lines
    assert "req_total 3" in lines
    assert "# TYPE pages gauge" in lines and "pages 7" in lines
    assert "# TYPE lat_seconds histogram" in lines
    # windowed bucket counts, but +Inf/_sum/_count from the cumulative
    # track: a scrape after a window reset must stay monotonic
    assert 'lat_seconds_bucket{le="0.5"} 1' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 3' in lines
    assert "lat_seconds_count 3" in lines
    assert "lat_seconds_sum 2.75" in lines
    assert text.endswith("\n")


def test_flight_recorder_ring_and_chrome_trace():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(0)
    fr = FlightRecorder(capacity=2)
    for i in range(3):
        fr.record({"step": i, "t_start": float(i), "dur_s": 0.5,
                   "phases": {"schedule": 0.1, "decode": 0.4},
                   "events": 2, "pages_used": 4, "quarantined": [],
                   "faults_fired": 0})
    assert [r["step"] for r in fr.records] == [1, 2]   # ring dropped step 0
    dump = fr.dump()
    assert fr.dumps == 1 and len(dump) == 2
    dump[-1]["quarantined"] = [{"request_id": 7, "code": "failed",
                                "detail": "boom"}]
    dump[-1]["error"] = "EngineError: boom"
    trace = fr.to_chrome_trace(dump)
    assert fr.dumps == 1                      # rendering is not a dump
    evs = trace["traceEvents"]
    steps = [e for e in evs if e["cat"] == "step"]
    phases = [e for e in evs if e["cat"] == "phase"]
    faults = [e for e in evs if e["cat"] == "fault"]
    assert [e["ph"] for e in steps] == ["X", "X"]
    assert steps[0]["ts"] == 1.0 * 1e6 and steps[0]["dur"] == 0.5 * 1e6
    assert steps[0]["args"]["pages_used"] == 4
    # phase durations exact, laid out sequentially within the step
    assert phases[0]["ts"] == steps[0]["ts"]
    assert phases[1]["ts"] == phases[0]["ts"] + phases[0]["dur"]
    assert {e["name"] for e in faults} == {"quarantine", "engine-error"}
    assert all(e["ph"] == "i" for e in faults)
    json.dumps(trace)                         # must serialise as-is


# ---------------------------------------------------------------------------
# system fixtures (the same smoke engine the fault suite drives)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    from repro.models import build_model
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _core(built, *, injector=None, clock=None, **serve_kw):
    model, params, cfg = built
    serve_kw.setdefault("max_batch", 3)
    serve_kw.setdefault("max_seq_len", 96)
    serve_kw.setdefault("page_size", 16)
    serve_kw.setdefault("prefill_chunk", 16)
    serve_kw.setdefault("debug_invariants", True)
    return EngineCore(model, params, cfg, ServeConfig(**serve_kw),
                      injector=injector, clock=clock), cfg


def _drain(core, toks=None, max_steps=2000):
    toks = {} if toks is None else toks
    steps = 0
    while core.has_work:
        steps += 1
        assert steps <= max_steps, "engine failed to drain"
        for ev in core.step():
            if ev.kind == "token":
                toks.setdefault(ev.request_id, []).append(ev.token)
    return toks


class ManualClock:
    """Deterministic engine clock the test advances explicitly."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# system: every engine timing flows through the injectable clock
# ---------------------------------------------------------------------------

def test_frozen_clock_zeroes_every_engine_duration(built):
    """Regression for stray wall-clock reads: with the injected clock
    frozen, every duration the engine reports -- step time, phase
    breakdown, TTFT, TPOT, queue delay, e2e -- must be *exactly* 0.0
    even though real wall time passes.  Any code path still calling
    time.perf_counter()/time.monotonic() directly would mix real
    timestamps into the arithmetic and blow these sums up."""
    core, cfg = _core(built, clock=lambda: 1000.0)
    rng = np.random.default_rng(3)
    for i in range(4):
        core.add_request(rng.integers(0, cfg.vocab_size, size=5 + 7 * i),
                         SamplingParams(max_new_tokens=4), request_id=i)
    toks = _drain(core)
    assert len(toks) == 4
    assert core.step_s_high_water == 0.0
    m = core.metrics
    h_step = m["engine_step_seconds"]
    assert h_step.count == core.steps > 0 and h_step.sum == 0.0
    for name in m.names():
        if name.startswith("engine_phase_"):
            assert m[name].sum == 0.0, f"{name} saw a non-clock duration"
    for rec in core.tracer.completed:
        assert rec["first_token_t"] == rec["submit_t"] == rec["end_t"]
    assert m["engine_ttft_seconds"].count == 4
    assert m["engine_ttft_seconds"].sum == 0.0
    assert m["engine_e2e_seconds"].sum == 0.0
    # flight records carry the frozen timeline too
    assert all(r["dur_s"] == 0.0 for r in core.flight.records)


def test_engine_native_latency_matches_bench_arithmetic(built):
    """The acceptance check: TTFT/TPOT computed by the engine's
    lifecycle tracer equal a bench driver's own arithmetic *exactly*.
    The manual clock only moves between steps, so the in-step stamp the
    tracer takes and the post-step stamp the driver takes read the same
    value -- any disagreement is a bookkeeping bug, not timing noise."""
    clock = ManualClock()
    core, cfg = _core(built, clock=clock, max_batch=2)
    rng = np.random.default_rng(11)
    specs = {i: (rng.integers(0, cfg.vocab_size, size=4 + 9 * i), 3 + i)
             for i in range(4)}
    arrivals = {0: 0, 1: 0, 2: 2, 3: 5}      # 4 requests onto 2 slots:
    t_arrive, t_first, t_last, n_toks = {}, {}, {}, {}  # real queueing
    step_idx, pending = 0, sorted(specs)
    while pending or core.has_work:
        for rid in [r for r in pending if arrivals[r] <= step_idx]:
            prompt, n = specs[rid]
            core.add_request(prompt, SamplingParams(max_new_tokens=n),
                             request_id=rid)
            t_arrive[rid] = clock()
            pending.remove(rid)
        clock.advance(1.0)                   # the step "takes" 1s
        for ev in core.step():
            t_first.setdefault(ev.request_id, clock())
            t_last[ev.request_id] = clock()
            n_toks[ev.request_id] = n_toks.get(ev.request_id, 0) + 1
        step_idx += 1

    recs = {r["id"]: r for r in core.tracer.completed}
    assert sorted(recs) == sorted(specs)
    for rid in specs:
        rec = recs[rid]
        assert rec["reason"] == "finished"
        assert rec["n_tokens"] == n_toks[rid] == specs[rid][1]
        # exact equality -- no tolerance
        assert rec["first_token_t"] - rec["submit_t"] \
            == t_first[rid] - t_arrive[rid]
        if n_toks[rid] > 1:
            assert rec["tpot_s"] == (t_last[rid] - t_first[rid]) \
                / (n_toks[rid] - 1)
    # the histograms saw the same populations
    m = core.metrics
    assert m["engine_ttft_seconds"].count == len(specs)
    assert m["engine_tpot_seconds"].count == \
        sum(1 for r in specs if specs[r][1] > 1)
    # requests 2 and 3 arrived while both slots were busy: their queue
    # delay (submit -> first admission) must be visible and positive
    assert m["engine_queue_delay_seconds"].count == len(specs)
    assert m["engine_queue_delay_seconds"].window_max > 0.0
    assert core.tracer.open_span_count() == 0


# ---------------------------------------------------------------------------
# system: span lifecycle under preemption / swap / abort / quarantine
# ---------------------------------------------------------------------------

def test_spans_close_under_preemption_swap_and_abort(built):
    core, cfg = _core(built, num_pages=10, preempt_policy="swap")
    rng = np.random.default_rng(21)
    for i in range(4):                        # oversubscribed: 4 long
        core.add_request(rng.integers(0, cfg.vocab_size, size=30),
                         SamplingParams(max_new_tokens=30), request_id=i)
    for _ in range(6):
        core.step()
    assert core.abort(3)                      # client disconnect mid-run
    toks = _drain(core)
    stats = core.stats()
    assert stats["pressure"]["preemptions"] > 0, "pool never pressured"
    assert core.tracer.open_span_count() == 0, "leaked lifecycle spans"
    recs = {r["id"]: r for r in core.tracer.completed}
    assert recs[3]["reason"] == "aborted"
    assert all(recs[i]["reason"] == "finished" for i in toks if i != 3)
    m = core.metrics
    # every evict -> re-admit round trip was measured; the abort may
    # have cut request 3's last round trip short (that span closes
    # unobserved at the terminal, which is the point)
    preempts = stats["pressure"]["preemptions"]
    stalls = m["engine_preempt_stall_seconds"].count
    assert preempts - recs[3]["preemptions"] <= stalls <= preempts
    assert stalls > 0
    assert sum(r["preemptions"] for r in recs.values()) == preempts
    assert m["engine_requests_submitted_total"].window == 4
    assert m["engine_requests_finished_total"].window == len(toks)
    # the per-request trace journals the preemption round-trip
    preempted = [r for r in core.sched.finished
                 if any(e.startswith("preempted:") for e, _ in r.trace)]
    assert preempted, "no request journaled its preemption"
    for req in preempted:
        names = [e for e, _ in req.trace]
        if req.id == 3:
            continue                          # aborted before resuming
        assert "resumed" in names[names.index(
            next(e for e in names if e.startswith("preempted:"))):]


def test_quarantine_closes_spans_and_dumps_flight(built):
    inj = FaultInjector(seed=0).arm("sample", nth=(3,))
    core, cfg = _core(built, injector=inj)
    rng = np.random.default_rng(5)
    for i in range(3):
        core.add_request(rng.integers(0, cfg.vocab_size, size=6),
                         SamplingParams(max_new_tokens=4), request_id=i)
    errs = []
    while core.has_work:
        errs += [ev for ev in core.step() if ev.kind == "error"]
    assert len(errs) == 1, "exactly one request should be quarantined"
    victim = errs[0].request_id
    assert core.tracer.open_span_count() == 0
    recs = {r["id"]: r for r in core.tracer.completed}
    assert recs[victim]["reason"] == "failed"
    assert core.stats()["health"]["failed"] == 1
    # the quarantine dumped the flight recorder: the dump's quarantine
    # step names the victim, and it renders as valid Chrome JSON
    dump = core.last_flight_dump
    assert dump, "quarantine must dump the flight recorder"
    q = [e for r in dump for e in r["quarantined"]]
    assert [e["request_id"] for e in q] == [victim]
    assert q[0]["code"] == "failed" and "sample" in q[0]["detail"]
    trace = core.chrome_trace(dump)
    names = {e["name"] for e in trace["traceEvents"]}
    assert "quarantine" in names
    json.dumps(trace)


def test_forced_engine_error_carries_flight_dump(built):
    core, cfg = _core(built, num_pages=10)
    rng = np.random.default_rng(9)
    core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                     SamplingParams(max_new_tokens=3), request_id=0)
    _drain(core)                              # healthy steps fill the ring
    n_healthy = len(core.flight.records)
    assert n_healthy > 0
    core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                     SamplingParams(max_new_tokens=3), request_id=1)
    # force the unreachable-state tripwire: admission yields nothing for
    # a waiting request with no injector to blame
    core.sched.admit = lambda: []
    with pytest.raises(EngineError, match="pool too small") as ei:
        core.step()
    err = ei.value
    assert err.flight and err.flight == core.last_flight_dump
    assert len(err.flight) == n_healthy + 1   # ...plus the fatal step
    last = err.flight[-1]
    assert "pool too small" in last["error"]
    trace = core.chrome_trace(err.flight)
    assert any(e["name"] == "engine-error" for e in trace["traceEvents"])
    json.dumps(trace)


# ---------------------------------------------------------------------------
# system: stats() is a registry view; windows reset; trace-neutrality
# ---------------------------------------------------------------------------

def test_stats_reads_registry_windows_and_reset_reopens(built):
    core, cfg = _core(built)
    rng = np.random.default_rng(7)
    for i in range(3):
        core.add_request(rng.integers(0, cfg.vocab_size, size=4 + i),
                         SamplingParams(max_new_tokens=3), request_id=i)
    _drain(core)
    stats = core.stats()
    m = core.metrics
    assert stats["steps"] == m["engine_steps_total"].window > 0
    assert stats["events_emitted"] == m["engine_events_total"].window == 9
    assert stats["health"]["step_s_high_water"] \
        == m["engine_step_seconds"].window_max > 0.0
    total_before = m["engine_steps_total"].total
    peak_before = core.mgr.peak_used_pages

    core.reset_metrics_window()
    stats = core.stats()
    assert stats["steps"] == 0                # window view restarts...
    assert stats["health"]["step_s_high_water"] == 0.0
    assert core.mgr.peak_used_pages == core.mgr.used_pages == 0
    assert peak_before > 0
    assert not core.tracer.completed and not core.flight.records
    assert m["engine_steps_total"].total == total_before   # ...totals live
    assert f"engine_steps_total {total_before}" in core.export_prometheus()

    # engine.reset() keeps the registry (engine-lifetime, like the jit
    # caches): cumulative counters must survive a state reset
    core.reset()
    assert core.metrics is m
    assert m["engine_steps_total"].total == total_before


def test_telemetry_is_trace_neutral_and_bit_identical(built):
    _, _, cfg = built
    rng = np.random.default_rng(13)
    specs = {i: (rng.integers(0, cfg.vocab_size, size=s), 4)
             for i, s in enumerate((5, 40, 9))}

    def run(metrics_on):
        core, _ = _core(built, metrics=metrics_on, num_pages=13)
        for rid, (p, n) in specs.items():
            core.add_request(p, SamplingParams(max_new_tokens=n),
                             request_id=rid)
        return core, _drain(core)

    on_core, on_toks = run(True)
    off_core, off_toks = run(False)
    assert on_toks == off_toks
    assert on_core.prefill_trace_count == off_core.prefill_trace_count
    assert on_core.prefill_launches == off_core.prefill_launches
    assert on_core.steps == off_core.steps
    assert off_core.tracer is None and off_core.flight is None
    # metrics-off still keeps the stats() contract alive
    assert off_core.stats()["finished"] == len(specs)


def test_serve_engine_wrapper_shares_injectable_clock(built):
    """PR 10 satellite: the dense-path wrapper's measured durations ride
    the same injectable clock as ``EngineCore._clock`` -- one clock
    object governs every timing read in the serving stack."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built

    class Ticking:
        """Advances a fixed half second per read."""

        def __init__(self):
            self.t = 100.0
            self.reads = 0

        def __call__(self):
            self.reads += 1
            t, self.t = self.t, self.t + 0.5
            return t

    clock = Ticking()
    serve = ServeConfig(max_seq_len=96, page_size=16, prefill_chunk=16,
                        max_batch=2)
    engine = ServeEngine(model=model, params=params, cfg=cfg,
                         serve=serve, clock=clock)
    # the core created by the wrapper reads the *same* clock object
    assert engine.core._clock is engine._clock
    assert engine._clock is clock
    # wrapper-reported throughput is exactly determined by the injected
    # clock: two reads bracket the loop, dt == 0.5s
    before = clock.reads
    rate = engine.throughput_tokens_per_s(batch=2, prompt_len=8,
                                          n_new=4)
    assert clock.reads == before + 2
    assert rate == pytest.approx(2 * 4 / 0.5)


def test_serve_engine_default_clock_is_monotonic(built):
    import time as _time
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    engine = ServeEngine(model=model, params=params, cfg=cfg)
    assert engine._clock is _time.monotonic
