"""Hypothesis property tests on the system's core numerical invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, hnp, settings, st

from repro.kernels.fastattn.ref import flash_reference, standard_attention


@settings(max_examples=30, deadline=None)
@given(
    data=st.data(),
    sq=st.integers(1, 64),
    skv=st.integers(1, 96),
    block=st.sampled_from([16, 32, 64]),
)
def test_online_softmax_block_invariance(data, sq, skv, block):
    """flash(chunked) == standard for arbitrary shapes & block sizes."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    q = jnp.asarray(rng.normal(size=(1, 2, sq, 16)) * 3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, skv, 16)) * 3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, skv, 16)), jnp.float32)
    ref = standard_attention(q, k, v, causal=False)
    out = flash_reference(q, k, v, causal=False, block_kv=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(shift=st.floats(-50, 50), seed=st.integers(0, 1000))
def test_softmax_shift_invariance_with_softcap_disabled(shift, seed):
    """Attention output is invariant to adding a constant to all logits
    (softmax shift invariance) -- guards the m/l bookkeeping."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 12, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, 12, 16)), jnp.float32)
    base = flash_reference(q, k, v, causal=False, block_kv=4)
    # shifting K by a constant along the contraction does NOT shift logits
    # uniformly; instead test: scale==0 gives uniform attention == mean(V)
    out0 = flash_reference(q * 0, k, v, causal=False, block_kv=4)
    np.testing.assert_allclose(
        np.asarray(out0)[0, 0, 0], np.asarray(jnp.mean(v, axis=2))[0, 0],
        rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(base)).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), s=st.integers(2, 48))
def test_decode_matches_last_row_of_prefill(seed, s):
    """decode(q_t | cache) == row t of full causal attention."""
    from repro.kernels.fastattn.ref import decode_reference
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 2, s, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, s, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, s, 16)), jnp.float32)
    full = standard_attention(q, k, v, causal=True)
    last = decode_reference(q[:, :, -1:], k, v,
                            jnp.asarray([s], jnp.int32))
    np.testing.assert_allclose(np.asarray(last)[:, :, 0],
                               np.asarray(full)[:, :, -1],
                               rtol=1e-4, atol=1e-5)
