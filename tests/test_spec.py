"""Speculative decoding (serving/spec.py + the EngineCore verify path).

Unit level: the prompt-lookup drafter (longest-suffix matching,
incremental indexing, adaptive K from the accept-rate EMA) and the two
acceptance samplers as pure functions -- greedy acceptance IS the
argmax-prefix match, residual rejection sampling is seeded-deterministic
with the exact target marginal, and K=0 degenerates bit-for-bit into
``core.sample_token``.

System level: greedy token streams bit-identical with speculation on vs
off -- solo, under pool pressure (swap and recompute preemption), over
shared-prefix COW pages, and through the chaos soak with the
``spec_verify`` fault site armed -- plus replayable sampled acceptance,
batch-composition invariance, overhead-free ``spec_mode="off"`` (the
verify fn is never traced or launched), and the ``engine_spec_*``
metrics/flight-recorder surface.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.core import EngineCore, sample_token
from repro.serving.faults import FaultInjector, LogitError
from repro.serving.scheduler import Request, SamplingParams
from repro.serving.spec import (PromptLookupDrafter, verify_greedy,
                                verify_residual)


# ---------------------------------------------------------------------------
# unit: prompt-lookup drafter
# ---------------------------------------------------------------------------

def _req(prompt, generated=(), rid=0):
    r = Request(id=rid, prompt=np.asarray(prompt, np.int32),
                sampling=SamplingParams(max_new_tokens=64))
    r.generated = list(generated)
    return r


def test_drafter_proposes_continuation_of_previous_occurrence():
    d = PromptLookupDrafter(max_tokens=4, ngram_max=3, ngram_min=1)
    # ... 7 8 9 1 2 3 | suffix [1 2 3] matched earlier -> drafts [4 5 6 7]
    out = d.propose(_req([1, 2, 3, 4, 5, 6, 7, 8, 9, 1, 2, 3]))
    assert out == [4, 5, 6, 7]
    # most recent previous occurrence supplies the draft (end 5, not 2)
    d2 = PromptLookupDrafter(max_tokens=2, ngram_max=3, ngram_min=1)
    assert d2.propose(_req([5, 2, 9, 4, 2, 7, 8, 2])) == [7, 8]  # 1-gram "2"
    # no repetition at all -> nothing to draft
    d3 = PromptLookupDrafter(max_tokens=4)
    assert d3.propose(_req([1, 2, 3, 4, 5])) == []


def test_drafter_index_is_incremental_and_generation_aware():
    d = PromptLookupDrafter(max_tokens=4, ngram_max=2, ngram_min=1)
    r = _req([3, 1, 4], generated=[])
    assert d.propose(r) == []
    # generated tokens join the searchable context between calls
    r.generated = [1, 5, 9, 3, 1]
    out = d.propose(r)
    assert out == [4, 1, 5, 9]          # 2-gram [3, 1] seen at prompt start
    assert d._indexed[0] == 8


def test_drafter_adaptive_k_ema_and_forget():
    d = PromptLookupDrafter(max_tokens=4, ema_alpha=0.5)
    assert d.budget(0) == 4             # optimistic before any feedback
    d.observe(0, 4, 0)
    assert d.budget(0) == 1             # total rejection -> minimum K
    d.observe(0, 4, 4)                  # recovery pulls the EMA back up
    assert d.budget(0) == 2
    d.observe(0, 4, 4)
    assert d.budget(0) == 3
    d.forget(0)
    assert d.budget(0) == 4
    # ema_alpha=0 disables adaptation entirely
    d0 = PromptLookupDrafter(max_tokens=4, ema_alpha=0.0)
    d0.observe(0, 4, 0)
    assert d0.budget(0) == 4


def test_drafter_validation():
    with pytest.raises(ValueError, match="max_tokens"):
        PromptLookupDrafter(max_tokens=0)
    with pytest.raises(ValueError, match="ngram"):
        PromptLookupDrafter(max_tokens=2, ngram_min=3, ngram_max=2)
    with pytest.raises(ValueError, match="ema_alpha"):
        PromptLookupDrafter(max_tokens=2, ema_alpha=1.5)


# ---------------------------------------------------------------------------
# unit: greedy acceptance == argmax prefix match
# ---------------------------------------------------------------------------

def test_verify_greedy_accepts_exact_argmax_prefix():
    argm = [7, 8, 9, 3, 5]
    # full match: all 4 drafts + the bonus token from the last row
    toks, acc = verify_greedy([7, 8, 9, 3], argm, budget=64)
    assert (toks, acc) == ([7, 8, 9, 3, 5], 4)
    # mismatch at position 2: the emitted token IS the correction
    toks, acc = verify_greedy([7, 8, 1, 3], argm, budget=64)
    assert (toks, acc) == ([7, 8, 9], 2)
    # immediate mismatch degenerates to one (plain-decode) token
    toks, acc = verify_greedy([1, 8], argm, budget=64)
    assert (toks, acc) == ([7], 0)
    # K=0: just the bonus token -- the plain decode step
    assert verify_greedy([], argm, budget=64) == ([7], 0)
    # stop token ends acceptance without a bonus
    toks, acc = verify_greedy([7, 8, 9], argm, stop_ids=(8,), budget=64)
    assert (toks, acc) == ([7, 8], 2)
    # remaining-token budget caps the run
    toks, acc = verify_greedy([7, 8, 9, 3], argm, budget=2)
    assert (toks, acc) == ([7, 8], 2)


@pytest.mark.parametrize("seed", range(20))
def test_verify_greedy_prefix_property(seed):
    """For random drafts vs argmax rows: accepted == longest common
    prefix, and the emitted tokens are exactly the argmax stream."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(0, 5))
    argm = rng.integers(0, 4, size=k + 1)
    drafts = rng.integers(0, 4, size=k)
    toks, acc = verify_greedy(drafts, argm, budget=64)
    prefix = 0
    while prefix < k and drafts[prefix] == argm[prefix]:
        prefix += 1
    assert acc == prefix
    assert toks == [int(t) for t in argm[:min(prefix + 1, k + 1)]]


def test_verify_guard_checks_only_consumed_rows():
    argm = [7, 8, 9]
    ok = np.array([True, False, True])
    # mismatch at row 0 never consumes row 1 -> the bad row is ignored
    toks, acc = verify_greedy([1, 8], argm, budget=64, row_ok=ok)
    assert (toks, acc) == ([7], 0)
    # accepting through row 1 trips the guard
    with pytest.raises(LogitError):
        verify_greedy([7, 8], argm, budget=64, row_ok=ok)
    with pytest.raises(LogitError):
        verify_residual([7], np.zeros((2, 4), np.float32), seed=0, n0=0,
                        temperature=1.0, budget=64,
                        row_ok=np.array([False, True]))


# ---------------------------------------------------------------------------
# unit: residual rejection sampling
# ---------------------------------------------------------------------------

def test_verify_residual_seeded_deterministic():
    rng = np.random.default_rng(0)
    rows = rng.normal(size=(5, 16)).astype(np.float32)
    drafts = [3, 11, 7, 2]
    a = verify_residual(drafts, rows, seed=9, n0=4, temperature=0.7,
                        top_k=8, budget=64)
    b = verify_residual(drafts, rows, seed=9, n0=4, temperature=0.7,
                        top_k=8, budget=64)
    assert a == b                       # replayable from (seed, n0) alone
    assert 1 <= len(a[0]) <= 5 and 0 <= a[1] <= 4


def test_verify_residual_k0_bit_identical_to_sample_token():
    """A draft-less verify step must sample exactly like the plain
    decode path: same key (fold_in(PRNGKey(seed), n)), same processing,
    same bits."""
    rng = np.random.default_rng(1)
    row = rng.normal(size=(32,)).astype(np.float32)
    for n0, seed, temp, top_k in [(0, 0, 1.0, 0), (7, 3, 0.6, 5),
                                  (2, 11, 1.3, 0)]:
        toks, acc = verify_residual([], [row], seed=seed, n0=n0,
                                    temperature=temp, top_k=top_k,
                                    budget=64)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), n0)
        want = int(np.asarray(sample_token(
            jnp.atleast_2d(jnp.asarray(row)), key, temperature=temp,
            top_k=top_k)).ravel()[0])
        assert (toks, acc) == ([want], 0)


def test_verify_residual_marginal_matches_target():
    """Accept-or-residual over a point-mass drafter must emit each token
    with its target probability p(t) -- including the drafted token.
    Empirical check over many token indices (each index draws fresh
    counter-based keys)."""
    logits = np.array([1.5, 0.5, -0.5, 0.0], np.float32)
    p = np.asarray(jax.nn.softmax(jnp.asarray(logits)))
    draft = 1
    counts = np.zeros(4)
    trials = 1200
    for n in range(trials):
        toks, _ = verify_residual([draft], [logits, logits], seed=5, n0=n,
                                  temperature=1.0, budget=64)
        counts[toks[0]] += 1
    freq = counts / trials
    np.testing.assert_allclose(freq, p, atol=0.06)


# ---------------------------------------------------------------------------
# system fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    from repro.models import build_model
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _serve(**kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq_len", 96)
    kw.setdefault("page_size", 16)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("debug_invariants", True)
    return ServeConfig(**kw)


def _spec_on(serve, **kw):
    kw.setdefault("spec_mode", "lookup")
    kw.setdefault("spec_tokens", 4)
    return dataclasses.replace(serve, **kw)


def _prompts(cfg, repetitive=True, n=3, seed=0):
    """Lookup-friendly prompts (tiled motif) or unrepetitive ones."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size
    out = []
    for i in range(n):
        if repetitive:
            motif = rng.integers(1, v, size=5).tolist()
            out.append(np.array((motif * 8)[:20 + 4 * i], np.int32))
        else:
            out.append(rng.integers(1, v, size=12 + 3 * i).astype(np.int32))
    return out


def _run(built, serve, prompts, *, injector=None, temps=None, seed=11,
         max_new=16, waves=1):
    """Drive an EngineCore to idle; returns ({rid: [tokens]}, core).
    ``waves > 1`` resubmits the same prompts after draining (prefix-
    cache warm path)."""
    model, params, cfg = built
    core = EngineCore(model, params, cfg, serve, injector=injector)
    outs = {}
    rid = 0
    for _ in range(waves):
        for i, p in enumerate(prompts):
            sp = SamplingParams(
                max_new_tokens=max_new,
                temperature=0.0 if temps is None else temps[i],
                seed=seed + i)
            core.add_request(p, sp, request_id=rid)
            outs[rid] = []
            rid += 1
        while core.has_work:
            for ev in core.step():
                if ev.kind == "token":
                    outs[ev.request_id].append(ev.token)
    core.mgr.check_invariants(
        extern_refs=core.prefix.page_refs() if core.prefix else None)
    return outs, core


# ---------------------------------------------------------------------------
# system: bit-identity, degeneration, invariance
# ---------------------------------------------------------------------------

def test_spec_greedy_bit_identical_and_fewer_steps(built):
    prompts = _prompts(built[2])
    off, core_off = _run(built, _serve(), prompts)
    on, core_on = _run(built, _spec_on(_serve()), prompts)
    assert on == off                    # greedy stream invariant to spec
    st = core_on.stats()["spec"]
    assert st["drafted"] > 0 and st["accept_rate"] > 0.3
    assert st["verify_launches"] > 0
    # accepted runs collapse steps: same tokens, fewer iterations
    assert core_on.stats()["steps"] < core_off.stats()["steps"]
    # the off engine provably never touched the verify path
    assert core_off.spec_launches == 0
    assert core_off.spec_trace_count == 0
    assert "spec" not in core_off.stats()


def test_spec_verify_fault_degrades_to_k0_bit_identical(built):
    """spec_verify armed every step -> every verify launch carries zero
    drafts; tokens still bit-identical to the plain path."""
    prompts = _prompts(built[2])
    off, _ = _run(built, _serve(), prompts)
    inj = FaultInjector(seed=0).arm("spec_verify", every=1)
    on, core = _run(built, _spec_on(_serve()), prompts, injector=inj)
    assert on == off
    st = core.stats()["spec"]
    assert st["drafted"] == 0 and st["verify_launches"] > 0


def test_spec_sampled_replay_and_batch_composition_invariance(built):
    prompts = _prompts(built[2])
    temps = [0.8, 0.9, 0.7]
    a, _ = _run(built, _spec_on(_serve()), prompts, temps=temps)
    b, _ = _run(built, _spec_on(_serve()), prompts, temps=temps)
    assert a == b                       # counter-based RNG: replayable
    solo, _ = _run(built, _spec_on(_serve()), prompts[:1], temps=temps[:1])
    assert solo[0] == a[0]              # co-tenants change nothing


def test_spec_greedy_bit_identical_under_pressure(built):
    """Preemption mid-speculation: grown-but-unwritten rows are dropped
    with the victim's pages and the resume path never sees them."""
    prompts = _prompts(built[2])
    for policy in ("swap", "recompute"):
        serve = _serve(num_pages=8, preempt_policy=policy)
        off, _ = _run(built, serve, prompts)
        on, core = _run(built, _spec_on(serve), prompts)
        assert on == off, policy
        assert core.stats()["pressure"]["preemptions"] > 0, policy


def test_spec_greedy_bit_identical_with_shared_prefix_cow(built):
    """Two waves over a shared system prompt: wave 2 decodes (and
    speculates) off prefix-cache hits, COW-protecting shared tail pages
    that the multi-token append must copy before writing."""
    cfg = built[2]
    rng = np.random.default_rng(4)
    sysp = rng.integers(1, cfg.vocab_size, size=32).tolist()
    motif = rng.integers(1, cfg.vocab_size, size=5).tolist()
    prompts = [np.array(sysp + (motif * 5)[:14], np.int32),
               np.array(sysp + (motif * 4)[:10], np.int32)]
    serve = _serve(max_batch=2, prefix_cache=True)
    off, _ = _run(built, serve, prompts, waves=2)
    on, core = _run(built, _spec_on(serve), prompts, waves=2)
    assert on == off
    assert core.stats()["prefix"]["hits"] > 0
    assert core.stats()["spec"]["accepted"] > 0


def test_spec_chaos_soak_survivors_bit_identical(built):
    """Invariants every step under a fault storm covering the new
    spec_verify site plus page_alloc/sample/decode_launch: quarantined
    requests fail cleanly, survivors match the fault-free plain run bit
    for bit, nothing leaks."""
    prompts = _prompts(built[2], n=4, seed=2)
    ref, _ = _run(built, _serve(max_batch=3), prompts, max_new=12)
    inj = (FaultInjector(seed=5)
           .arm("spec_verify", every=4)
           .arm("decode_launch", nth=(3,))
           .arm("page_alloc", nth=(6,))
           .arm("sample", nth=(9,)))
    on, core = _run(built, _spec_on(_serve(max_batch=3)), prompts,
                    injector=inj, max_new=12)
    survivors = {r: t for r, t in on.items() if len(t) == 12}
    assert survivors and all(ref[r] == t for r, t in survivors.items())
    assert core.injector.total_fired > 0
    assert core.stats()["active_slots"] == 0
    assert core.mgr.used_pages == (core.prefix.cached_pages
                                   if core.prefix else 0)


def test_spec_stop_token_inside_accepted_run(built):
    """A stop token accepted mid-run ends the request exactly where the
    plain path would: no token after the stop, KV rolled back to the
    invariant length."""
    prompts = _prompts(built[2], n=1)
    base, _ = _run(built, _serve(max_batch=1), prompts, max_new=16)
    stop = base[0][5]                   # force a stop mid-generation
    def with_stop(serve):
        outs = {}
        model, params, cfg = built
        core = EngineCore(model, params, cfg, serve)
        core.add_request(prompts[0], SamplingParams(
            max_new_tokens=16, stop_token_ids=(stop,)), request_id=0)
        outs[0] = []
        while core.has_work:
            for ev in core.step():
                if ev.kind == "token":
                    outs[0].append(ev.token)
        return outs
    off = with_stop(_serve(max_batch=1))
    on = with_stop(_spec_on(_serve(max_batch=1)))
    assert on == off and on[0][-1] == stop
    assert len(on[0]) <= 6 + 1


def test_spec_metrics_and_flight_recorder_surface(built):
    prompts = _prompts(built[2])
    _, core = _run(built, _spec_on(_serve()), prompts)
    snap = core.metrics.snapshot()
    assert snap["engine_spec_drafted_total"]["window"] > 0
    assert snap["engine_spec_accepted_total"]["window"] > 0
    assert snap["engine_spec_accept_rate"]["count"] > 0
    assert snap["engine_spec_run_length"]["count"] > 0
    # the verify launch is its own step phase, in the phase histograms
    # and the flight-recorder ring / Chrome trace
    assert "engine_phase_verify_seconds" in snap
    assert any("verify" in r["phases"] for r in core.flight.records)
    names = {e["name"] for e in core.chrome_trace()["traceEvents"]
             if e.get("ph") == "X"}
    assert "verify" in names


def test_spec_config_validation(built):
    model, params, cfg = built
    with pytest.raises(ValueError, match="spec_mode"):
        EngineCore(model, params, cfg, _serve(spec_mode="draft-model"))
    with pytest.raises(ValueError, match="spec_tokens"):
        EngineCore(model, params, cfg,
                   _serve(spec_mode="lookup", spec_tokens=0))
