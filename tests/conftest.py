import numpy as np
import pytest

# NOTE: no XLA_FLAGS here -- smoke tests and benches must see 1 device.
# Distribution tests build their own small meshes in subprocesses or use
# the single device.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
