"""Property-style tests for the paged KV cache manager.

Random admit/append/retire traces (seeded numpy rng, no hypothesis
dependency) must preserve the pool invariants after every operation: no
page leaked, none double-owned, none both owned and free, the scratch
page never allocated, and the logical->physical mapping consistent with
the device page table.
"""
import numpy as np
import pytest

from repro.serving.paged_cache import OutOfPages, PagedKVCache, pages_needed


def test_pages_needed():
    assert pages_needed(0, 0, 16) == 0
    assert pages_needed(0, 1, 16) == 1
    assert pages_needed(0, 16, 16) == 1
    assert pages_needed(0, 17, 16) == 2
    assert pages_needed(16, 17, 16) == 1
    assert pages_needed(15, 16, 16) == 0
    assert pages_needed(5, 3, 16) == 0          # shrink never frees


def test_alloc_append_free_roundtrip():
    c = PagedKVCache(num_pages=8, page_size=4, max_slots=2,
                     max_pages_per_seq=4)
    assert c.free_pages == 7                    # page 0 is scratch
    c.alloc(0)
    c.append(0, 10)                             # 3 pages
    assert c.used_pages == 3 and c.seq_len(0) == 10
    c.check_invariants()
    page, off = c.physical(0, 9)
    assert page == c.table[0, 2] and off == 1
    with pytest.raises(IndexError):
        c.physical(0, 10)                       # not materialised yet
    c.free(0)
    assert c.free_pages == 7 and c.seq_len(0) == 0
    assert (c.table[0] == 0).all()
    c.check_invariants()


def test_double_alloc_and_inactive_ops_raise():
    c = PagedKVCache(num_pages=4, page_size=4, max_slots=2,
                     max_pages_per_seq=2)
    c.alloc(0)
    with pytest.raises(ValueError):
        c.alloc(0)
    with pytest.raises(ValueError):
        c.append(1)
    with pytest.raises(ValueError):
        c.free(1)


def test_out_of_pages_and_per_seq_cap():
    c = PagedKVCache(num_pages=4, page_size=2, max_slots=2,
                     max_pages_per_seq=8)
    c.alloc(0)
    c.append(0, 6)                              # all 3 usable pages
    c.alloc(1)
    with pytest.raises(OutOfPages):
        c.append(1, 1)
    c.check_invariants()                        # failed append is a no-op
    assert c.seq_len(1) == 0
    c.free(0)
    c.append(1, 2)                              # freed pages reusable
    c.check_invariants()

    c2 = PagedKVCache(num_pages=64, page_size=2, max_slots=1,
                      max_pages_per_seq=2)
    c2.alloc(0)
    with pytest.raises(OutOfPages):
        c2.append(0, 5)                         # > max_pages_per_seq


def test_release_and_adopt_pages():
    """Preemption primitives: release returns the exact owned pages to
    the free list; adopt re-materialises a swapped length in one shot."""
    c = PagedKVCache(num_pages=8, page_size=4, max_slots=2,
                     max_pages_per_seq=4)
    c.alloc(0)
    new = c.append(0, 10)
    assert new == c.owned_pages(0) and len(new) == 3
    assert c.append(0, 1) == []                  # fits the tail page
    pages = c.release_pages(0)
    assert pages == new
    assert not c.is_active(0) and c.free_pages == 7 and c.seq_len(0) == 0
    c.check_invariants()

    got = c.adopt_pages(0, 9)
    assert len(got) == 3 and c.seq_len(0) == 9
    assert got == c.owned_pages(0)
    c.check_invariants()

    # failed adopt leaves the slot inactive and the pool untouched
    with pytest.raises(OutOfPages):
        c.adopt_pages(1, 100)
    assert not c.is_active(1)
    c.check_invariants()
    with pytest.raises(ValueError):
        c.release_pages(1)                       # inactive slot

    assert c.usable_pages == 7
    assert c.peak_utilization == pytest.approx(3 / 7)


def test_append_k_crosses_multiple_page_boundaries():
    """One multi-token append may materialise several pages (the
    speculative verify path grows 1+K rows at once)."""
    c = PagedKVCache(num_pages=8, page_size=4, max_slots=1,
                     max_pages_per_seq=6)
    c.alloc(0)
    first = c.append(0, 3)
    new = c.append(0, 10)                       # 3 -> 13 tokens: 1 -> 4 pages
    assert len(first) == 1 and len(new) == 3
    assert c.seq_len(0) == 13 and c.used_pages == 4
    assert c.physical(0, 3) == (first[0], 3)    # old tail kept
    assert c.physical(0, 4) == (new[0], 0)
    assert c.physical(0, 12) == (new[2], 0)
    c.check_invariants()


def test_truncate_basic_boundaries_and_errors():
    c = PagedKVCache(num_pages=8, page_size=4, max_slots=2,
                     max_pages_per_seq=4)
    c.alloc(0)
    pages = c.append(0, 10)                     # 3 pages
    # within the tail page: length shrinks, no page freed
    assert c.truncate(0, 9) == []
    assert c.seq_len(0) == 9 and c.owned_pages(0) == pages
    # crossing page boundaries: tail pages freed, table rows scratched
    assert c.truncate(0, 4) == pages[1:]
    assert c.owned_pages(0) == pages[:1]
    assert (c.table[0, 1:] == c.SCRATCH).all()
    c.check_invariants()
    # to zero: slot stays active with no pages (like a fresh alloc)
    assert c.truncate(0, 0) == pages[:1]
    assert c.is_active(0) and c.used_pages == 0 and c.seq_len(0) == 0
    c.check_invariants()
    c.append(0, 3)                              # still usable afterwards
    with pytest.raises(ValueError):
        c.truncate(0, 4)                        # beyond current length
    with pytest.raises(ValueError):
        c.truncate(0, -1)
    with pytest.raises(ValueError):
        c.truncate(1, 0)                        # inactive slot
    c.check_invariants()


def test_truncate_shared_pages_decref_only():
    """Truncating over pages shared with another slot (prefix hit) only
    drops this slot's reference -- the sharer keeps its KV."""
    c = PagedKVCache(num_pages=8, page_size=4, max_slots=2,
                     max_pages_per_seq=4)
    c.alloc(0)
    pages = c.append(0, 6)                      # 2 pages, tail half-full
    c.alloc(1)
    c.share_pages(1, pages, 6)
    assert c.refcount(pages[1]) == 2
    assert c.truncate(1, 4) == [pages[1]]
    assert c.refcount(pages[1]) == 1            # still resident for slot 0
    assert c.owned_pages(0) == pages and c.owned_pages(1) == pages[:1]
    assert c.seq_len(0) == 6
    c.check_invariants()


def test_truncate_right_after_cow_cancels_dead_debt():
    """Append-K onto a shared tail COWs it; rolling the speculative rows
    back before the device copy ran must keep the debt only while its
    destination page is still owned -- a cancelled dst went back to the
    free list and may be reallocated at any moment."""
    c = PagedKVCache(num_pages=8, page_size=4, max_slots=2,
                     max_pages_per_seq=4)
    c.alloc(0)
    pages = c.append(0, 6)
    c.alloc(1)
    c.share_pages(1, pages, 6)
    fresh = c.append(1, 5)                      # COW tail + 1 new page
    assert len(fresh) == 1 and len(c.cow_pending) == 1
    src, dst = c.cow_pending[0]
    assert src == pages[1] and dst == c.owned_pages(1)[1]
    # rollback that keeps the COW'd tail page: the debt must survive
    # (rows 4..5 live on the copy)
    assert c.truncate(1, 6) == fresh
    assert c.cow_pending == [(src, dst)]
    c.check_invariants()
    # rollback past the COW'd page: the debt dies with it
    assert c.truncate(1, 4) == [dst]
    assert c.cow_pending == []
    assert c.refcount(src) == 1 and c.refcount(dst) == 0
    c.check_invariants()


def test_mapping_roundtrip_random_lengths():
    rng = np.random.default_rng(0)
    c = PagedKVCache(num_pages=40, page_size=8, max_slots=4,
                     max_pages_per_seq=8)
    lens = [1, 8, 9, 40]
    for slot, n in enumerate(lens):
        c.alloc(slot)
        c.append(slot, n)
    table = c.device_table()
    for slot, n in enumerate(lens):
        owned = c.owned_pages(slot)
        for pos in rng.integers(0, n, size=20):
            page, off = c.physical(slot, int(pos))
            # physical() agrees with the device table the kernel reads
            assert page == table[slot, pos // 8]
            assert off == pos % 8
            assert page == owned[pos // 8]
    c.check_invariants()


@pytest.mark.parametrize("seed", range(5))
def test_random_trace_no_leak_no_double_own(seed):
    """Random admit/append/retire/share traffic with external (prefix
    index style) holds: the refcount invariants hold at every step --
    every owned/shared page accounted once per holder, free pages have
    refcount 0, scratch never refcounted -- and a fully drained pool
    returns to its initial state."""
    rng = np.random.default_rng(seed)
    c = PagedKVCache(num_pages=24, page_size=4, max_slots=6,
                     max_pages_per_seq=6)
    extern: dict = {}                           # page -> external holds
    for _ in range(400):
        op = rng.choice(["alloc", "append", "free", "release", "adopt",
                         "share", "hold", "unhold", "spec"])
        slot = int(rng.integers(0, c.max_slots))
        try:
            if op == "alloc":
                c.alloc(slot)
            elif op == "append":
                c.append(slot, int(rng.integers(1, 6)))
            elif op == "release":
                c.release_pages(slot)
            elif op == "adopt":
                c.adopt_pages(slot, int(rng.integers(1, 12)))
            elif op == "share":
                # mirror an admission prefix hit: point an empty slot at
                # a prefix of some other slot's pages, non-aligned
                # lengths included (the COW-protected shared tail)
                src = int(rng.integers(0, c.max_slots))
                pages = c.owned_pages(src)
                k = int(rng.integers(1, len(pages) + 1)) if pages else 0
                n = int(rng.integers((k - 1) * c.page_size + 1,
                                     k * c.page_size + 1)) if k else 0
                c.alloc(slot)
                try:
                    c.share_pages(slot, pages[:k], n)
                except ValueError:
                    c.free(slot)
                    raise
            elif op == "hold":
                # external hold, like the prefix index taking a block
                owned = [p for pages in c._pages for p in pages]
                if owned:
                    page = owned[int(rng.integers(0, len(owned)))]
                    c.incref(page)
                    extern[page] = extern.get(page, 0) + 1
            elif op == "unhold":
                if extern:
                    page = list(extern)[int(rng.integers(0, len(extern)))]
                    c.decref(page)
                    extern[page] -= 1
                    if not extern[page]:
                        del extern[page]
            elif op == "spec":
                # speculative verify shape: append K rows (may COW a
                # shared tail) then roll back to an arbitrary accept
                # point BEFORE the COW device copy ran -- truncate must
                # cancel exactly the debts whose dst page it freed
                cur = c.seq_len(slot)
                c.append(slot, int(rng.integers(1, 6)))
                c.truncate(slot, int(rng.integers(0, cur + 1))
                           if rng.integers(0, 2) else cur)
            else:
                c.free(slot)
        except (ValueError, OutOfPages):
            pass                                # rejected ops are no-ops
        c.check_invariants(extern_refs=extern)
        # every surviving COW debt must point at live pages: the src is
        # still held by a sharer, the dst is still owned by the grower
        free = set(c._free)
        for s, d in c.cow_pending:
            assert c.refcount(s) > 0 and c.refcount(d) > 0
            assert s not in free and d not in free
        c.cow_pending.clear()                   # "device copy" applied
    for slot in range(c.max_slots):
        if c.is_active(slot):
            c.free(slot)
    for page, n in list(extern.items()):
        for _ in range(n):
            c.decref(page)
    c.check_invariants(extern_refs={})
    assert c.used_pages == 0 and c.free_pages == 23
    assert (c.device_table() == 0).all()
    assert c.peak_used_pages <= 23


def test_lifo_page_reuse():
    """Freshly freed pages are handed out first (LIFO free list)."""
    c = PagedKVCache(num_pages=16, page_size=4, max_slots=2,
                     max_pages_per_seq=4)
    c.alloc(0)
    c.append(0, 8)
    pages = c.owned_pages(0)
    c.free(0)
    c.alloc(1)
    c.append(1, 8)
    assert c.owned_pages(1) == pages
