"""EngineCore step API: persistent engine, per-request SamplingParams,
counter-based RNG, stop tokens, abort, and the generate_stream shim.

Unit level: SamplingParams normalisation and aliases, multi-stop /
stop-on-first-token semantics, scheduler.abort bookkeeping.  System
level: driving ``step()`` directly (add mid-flight, abort mid-prefill,
invariants every step, late request bit-identical to a solo run),
abort at every lifecycle stage without page leaks, sampled-token
reproducibility across batch compositions at temperature > 0, and the
deprecation contract of the engine-global sampling knobs.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.core import EngineCore, StreamEvent
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import (ABORTED, FINISHED, PREFILLING, RUNNING,
                                     ContinuousBatchScheduler, Request,
                                     SamplingParams)


# ---------------------------------------------------------------------------
# unit: SamplingParams / Request aliases / stop tokens
# ---------------------------------------------------------------------------

def test_sampling_params_normalise_and_validate():
    sp = SamplingParams(stop_token_ids={7, 3, 7})
    assert sp.stop_token_ids == (3, 7)            # set -> sorted tuple
    assert sp.greedy                              # temperature 0 default
    assert not SamplingParams(temperature=0.5).greedy
    assert SamplingParams(temperature=0.5, top_k=1).greedy
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-1.0)


def test_request_aliases_fold_into_sampling():
    # eos_id joins the stop set, max_new_tokens= overrides
    r = Request(id=0, prompt=np.array([1, 2]), max_new_tokens=5, eos_id=9,
                sampling=SamplingParams(stop_token_ids=(4,)))
    assert r.sampling.stop_token_ids == (4, 9)
    assert r.max_new_tokens == r.sampling.max_new_tokens == 5
    # sampling alone drives length; aliases alone still work (legacy)
    r2 = Request(id=1, prompt=np.array([1]),
                 sampling=SamplingParams(max_new_tokens=3))
    assert r2.max_new_tokens == 3
    r3 = Request(id=2, prompt=np.array([1]), max_new_tokens=4, eos_id=7)
    assert r3.sampling is None and r3.stop_token_ids == (7,)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(id=3, prompt=np.array([1]))


def test_multi_stop_and_stop_on_first_token():
    r = Request(id=0, prompt=np.array([1, 2]),
                sampling=SamplingParams(max_new_tokens=100,
                                        stop_token_ids={7, 11}))
    assert not r.done
    r.generated = [3, 4]
    assert not r.done
    r.generated = [3, 11]                         # second stop id works
    assert r.done
    r.generated = [7]                             # stop on first token
    assert r.done
    r.generated = [3, 7, 5]                       # only the LAST counts
    assert not r.done


def test_eos_alias_still_finishes_early():
    r = Request(id=0, prompt=np.array([1, 2]), max_new_tokens=100,
                eos_id=7)
    r.generated = [3, 7]
    assert r.done


def test_scheduler_abort_releases_pages_and_cow_debt():
    cache = PagedKVCache(num_pages=8, page_size=4, max_slots=2,
                         max_pages_per_seq=4)
    sched = ContinuousBatchScheduler(cache)
    a, b = (Request(id=0, prompt=np.arange(4), max_new_tokens=4),
            Request(id=1, prompt=np.arange(4), max_new_tokens=4))
    sched.submit(a)
    sched.submit(b)
    sched.admit()
    # b shares a's partially-filled tail page, then COWs off it
    pages = cache.append(0, 2)
    cache.free(1)                                 # back to an empty slot
    cache.alloc(1)
    cache.share_pages(1, pages, 2)
    cache.append(1, 1)                            # COW: slot 1 moves
    assert cache.cow_pending
    free0 = cache.free_pages
    assert sched.abort(1) is b and b.state == ABORTED
    assert not cache.cow_pending                  # debt died with it
    assert cache.free_pages == free0 + 1          # its COW page came back
    assert sched.slots[1] is None
    cache.check_invariants()
    # unknown / repeated aborts are no-ops
    assert sched.abort(1) is None
    assert sched.abort(99) is None


# ---------------------------------------------------------------------------
# system fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    from repro.models import build_model
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _core(built, **serve_kw):
    model, params, cfg = built
    serve_kw.setdefault("max_batch", 3)
    serve_kw.setdefault("max_seq_len", 96)
    serve_kw.setdefault("page_size", 16)
    serve_kw.setdefault("prefill_chunk", 16)
    serve_kw.setdefault("debug_invariants", True)
    return EngineCore(model, params, cfg,
                      ServeConfig(**serve_kw)), cfg


def _drain(core, ids=None):
    """step() until idle; returns {request_id: [tokens]} of the events."""
    out = {}
    while core.has_work:
        for ev in core.step():
            out.setdefault(ev.request_id, []).append(ev.token)
    if ids is not None:
        assert set(out) >= set(ids)
    return out


def _solo_tokens(core, prompt, sampling, rid=900):
    core.add_request(prompt, sampling, request_id=rid)
    return _drain(core)[rid]


# ---------------------------------------------------------------------------
# system: the step API end to end (the acceptance scenario)
# ---------------------------------------------------------------------------

def test_engine_core_step_api_end_to_end(built):
    """Add 3 requests, step a few times, add a 4th mid-flight, abort one
    mid-prefill, drain: invariants hold every step, events are
    well-formed, and the late request's tokens match a solo run."""
    core, cfg = _core(built, num_pages=13)
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab_size, size=s)
               for i, s in enumerate((5, 40, 9, 12))}
    sp = SamplingParams(max_new_tokens=6)
    for i in range(3):
        assert core.add_request(prompts[i], sp) == i
    assert core.has_work and core.stats()["waiting"] == 3

    events = []
    for _ in range(2):
        events += core.step()
        core.mgr.check_invariants(
            extern_refs=core.prefix.page_refs() if core.prefix else None)
    # request 1 (40-token prompt, 16-token chunks under a 16-token
    # budget) is still prefilling after 2 steps; abort it mid-prefill
    assert core.requests[1].state == PREFILLING
    held = set(core.mgr.owned_pages(core.requests[1].slot))
    assert held, "mid-prefill victim held no pages"
    assert core.abort(1)
    assert not core.abort(1)                      # idempotent
    core.mgr.check_invariants()
    # a 4th request arrives mid-flight
    assert core.add_request(prompts[3], sp) == 3
    late = core.requests[3]
    while core.has_work:
        events += core.step()
        core.mgr.check_invariants(
            extern_refs=core.prefix.page_refs() if core.prefix else None)
    assert late.state == FINISHED
    assert core.mgr.used_pages == 0, "pages leaked after drain"

    by_req = {}
    for ev in events:
        by_req.setdefault(ev.request_id, []).append(ev)
    assert 1 not in by_req or len(by_req[1]) == 0  # aborted: no tokens
    for rid in (0, 2, 3):
        evs = by_req[rid]
        assert [e.index for e in evs] == list(range(6))
        assert [e.finished for e in evs] == [False] * 5 + [True]

    # the late request's tokens match a solo run on a fresh core
    solo, _ = _core(built, num_pages=13)
    assert _solo_tokens(solo, prompts[3], sp) == \
        [e.token for e in by_req[3]]


def test_abort_waiting_and_unknown(built):
    core, cfg = _core(built, num_pages=13)
    rng = np.random.default_rng(1)
    rid = core.add_request(rng.integers(0, cfg.vocab_size, size=4),
                           SamplingParams(max_new_tokens=3))
    assert core.abort(rid)                        # still WAITING
    assert not core.has_work
    assert not core.abort(rid) and not core.abort(12345)
    assert core.stats()["aborts"] == 1


def test_abort_mid_decode_frees_pages_for_reuse(built):
    core, cfg = _core(built, num_pages=13)
    rng = np.random.default_rng(2)
    rid = core.add_request(rng.integers(0, cfg.vocab_size, size=20),
                           SamplingParams(max_new_tokens=40))
    while core.requests[rid].state != RUNNING:
        core.step()
    for _ in range(2):
        core.step()                               # a few decode tokens
    held = set(core.mgr.owned_pages(core.requests[rid].slot))
    assert held
    assert core.abort(rid)
    core.mgr.check_invariants()
    assert core.mgr.used_pages == 0
    # a subsequent request reuses the freed physical pages (LIFO list)
    rid2 = core.add_request(rng.integers(0, cfg.vocab_size, size=20),
                            SamplingParams(max_new_tokens=2))
    core.step()                                   # admit + first chunk
    req2 = core.requests[rid2]
    assert set(core.mgr.owned_pages(req2.slot)) & held, \
        "freed pages not reused"
    _drain(core)
    assert req2.state == FINISHED
    assert core.mgr.used_pages == 0


def test_abort_while_swap_preempted_drops_stash(built):
    """Force a swap preemption, then abort the victim while it waits in
    the resuming queue: the host stash is dropped, nothing leaks, and
    the surviving request still finishes."""
    core, cfg = _core(built, num_pages=7, preempt_policy="swap",
                      max_batch=2)
    rng = np.random.default_rng(3)
    a = core.add_request(rng.integers(0, cfg.vocab_size, size=8),
                         SamplingParams(max_new_tokens=60))
    b = core.add_request(rng.integers(0, cfg.vocab_size, size=8),
                         SamplingParams(max_new_tokens=60))
    while core.pressure.stats["swaps"] == 0:
        assert core.has_work
        core.step()
    victim = next(r.id for r in core.sched.resuming)
    assert core.pressure.holds(victim)
    assert core.abort(victim)
    assert not core.pressure.holds(victim)
    assert core.pressure.stats["abort_drops"] == 1
    core.mgr.check_invariants()
    _drain(core)
    survivor = a if victim == b else b
    req = next(r for r in core.sched.finished if r.id == survivor)
    assert req.state == FINISHED and len(req.generated) == 60
    assert len(core.pressure.host_pool) == 0, "stash leaked"
    assert core.mgr.used_pages == 0


def test_abort_while_holding_shared_prefix_pages(built):
    """Aborting a request that shares radix-cached prefix pages only
    drops its references: the index keeps the pages, refcounts balance
    (extern-aware invariants), and a later request still hits them."""
    core, cfg = _core(built, prefix_cache=True)
    rng = np.random.default_rng(4)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=32)   # 2 pages

    def make_prompt(n):
        return np.concatenate(
            [sys_prompt, rng.integers(0, cfg.vocab_size, size=n)])

    sp = SamplingParams(max_new_tokens=4)
    core.add_request(make_prompt(5), sp, request_id=0)
    _drain(core)                                   # seed the index
    rid = core.add_request(make_prompt(6), sp, request_id=1)
    core.step()                                    # admitted + sharing
    req = core.requests[rid]
    assert req.matched_len == 32, "prefix not shared"
    shared = set(core.mgr.owned_pages(req.slot)[:2])
    assert all(core.mgr.refcount(p) >= 2 for p in shared)
    assert core.abort(rid)
    core.mgr.check_invariants(extern_refs=core.prefix.page_refs())
    # the index still holds the shared pages for the next request
    assert all(core.mgr.refcount(p) == 1 for p in shared)
    rid3 = core.add_request(make_prompt(7), sp, request_id=2)
    core.step()
    assert core.requests[rid3].matched_len == 32
    _drain(core)
    core.mgr.check_invariants(extern_refs=core.prefix.page_refs())


def test_reset_clears_everything(built):
    core, cfg = _core(built, prefix_cache=True)
    rng = np.random.default_rng(5)
    core.add_request(rng.integers(0, cfg.vocab_size, size=20),
                     SamplingParams(max_new_tokens=4))
    _drain(core)
    assert core.prefix.cached_pages > 0
    core.reset()
    assert not core.has_work
    assert core.mgr.used_pages == 0 and core.prefix.cached_pages == 0
    assert core.stats()["finished"] == 0
    # serves normally after the reset
    rid = core.add_request(rng.integers(0, cfg.vocab_size, size=8),
                           SamplingParams(max_new_tokens=3))
    assert len(_drain(core)[rid]) == 3


# ---------------------------------------------------------------------------
# system: per-request counter-based RNG
# ---------------------------------------------------------------------------

def test_sampled_tokens_invariant_to_batch_composition(built):
    """temperature > 0: a request's sampled tokens depend only on its
    prompt and SamplingParams.seed -- not on co-tenants, admission
    order, or preemption pressure around it."""
    sp = SamplingParams(temperature=0.7, top_k=8, seed=123,
                        max_new_tokens=8)
    rng = np.random.default_rng(6)
    _, _, cfg = built
    prompt = rng.integers(0, cfg.vocab_size, size=9)

    solo, _ = _core(built)
    alone = _solo_tokens(solo, prompt, sp)
    assert len(alone) == 8

    # same request mixed into a busy engine (greedy + other seeded
    # co-tenants, a long prompt prefilling, and an undersized pool
    # forcing preemptions)
    busy, _ = _core(built, num_pages=9, preempt_policy="swap")
    busy.add_request(rng.integers(0, cfg.vocab_size, size=40),
                     SamplingParams(max_new_tokens=10), request_id=50)
    busy.add_request(rng.integers(0, cfg.vocab_size, size=4),
                     SamplingParams(temperature=0.9, seed=7,
                                    max_new_tokens=20), request_id=51)
    busy.step()
    rid = busy.add_request(prompt, sp)             # arrives mid-flight
    mixed = _drain(busy)[rid]
    assert mixed == alone
    # identical seed + prompt on the same engine reproduces again
    assert _solo_tokens(busy, prompt, sp) == alone


def test_distinct_seeds_give_distinct_streams(built):
    core, cfg = _core(built)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=6)
    a = _solo_tokens(core, prompt,
                     SamplingParams(temperature=1.0, seed=1,
                                    max_new_tokens=12), rid=0)
    b = _solo_tokens(core, prompt,
                     SamplingParams(temperature=1.0, seed=2,
                                    max_new_tokens=12), rid=1)
    assert a != b


def test_stop_token_ends_generation_in_engine(built):
    """A stop id sampled mid-stream finishes the request early, and a
    stop on the very first token yields exactly one event."""
    core, cfg = _core(built)
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, size=7)
    greedy = _solo_tokens(core, prompt,
                          SamplingParams(max_new_tokens=8), rid=0)
    # stop on a mid-stream greedy token: the stream truncates at that
    # token's FIRST occurrence (the tiny model may repeat tokens)
    stop_tok = greedy[2]
    rid = core.add_request(prompt, SamplingParams(
        max_new_tokens=8, stop_token_ids={stop_tok}), request_id=1)
    toks = _drain(core)[rid]
    assert toks == greedy[:greedy.index(stop_tok) + 1]
    req = next(r for r in core.sched.finished if r.id == rid)
    assert req.state == FINISHED
    # stop on the first token
    rid = core.add_request(prompt, SamplingParams(
        max_new_tokens=8, stop_token_ids={greedy[0], 100000}),
        request_id=2)
    evs = []
    while core.has_work:
        evs += core.step()
    evs = [e for e in evs if e.request_id == rid]
    assert len(evs) == 1 and evs[0].finished
    assert evs[0].token == greedy[0]


# ---------------------------------------------------------------------------
# system: generate_stream is a thin shim over the core
# ---------------------------------------------------------------------------

def test_generate_stream_matches_core_and_persists(built):
    """The wrapper's greedy events are exactly what driving the core by
    hand produces, and both run on the same persistent state."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    serve = ServeConfig(max_batch=3, max_seq_len=96, page_size=16,
                        prefill_chunk=16, debug_invariants=True)
    engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)
    rng = np.random.default_rng(9)
    spec = [(5, 6), (23, 3), (9, 4)]
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab_size, size=s),
                    sampling=SamplingParams(max_new_tokens=n))
            for i, (s, n) in enumerate(spec)]
    events = list(engine.generate_stream(reqs))
    assert engine.core.steps > 0                   # same core underneath

    core, _ = _core(built)
    for i, (s, n) in enumerate(spec):
        core.add_request(reqs[i].prompt,
                         SamplingParams(max_new_tokens=n), request_id=i)
    direct = []
    while core.has_work:
        direct += core.step()
    assert [tuple(e) for e in events] == [tuple(e) for e in direct]
    assert isinstance(direct[0], StreamEvent)


def test_interleaved_streams_route_all_events(built):
    """Two generate_stream calls advanced alternately share the one
    persistent core: a step driven by either drain may produce the
    other's tokens, which must be buffered and delivered -- not
    dropped."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    serve = ServeConfig(max_batch=3, max_seq_len=96, page_size=16,
                        prefill_chunk=16, debug_invariants=True)
    engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)
    rng = np.random.default_rng(13)
    r1 = Request(id=0, prompt=rng.integers(0, cfg.vocab_size, size=6),
                 sampling=SamplingParams(max_new_tokens=7))
    r2 = Request(id=10, prompt=rng.integers(0, cfg.vocab_size, size=9),
                 sampling=SamplingParams(max_new_tokens=5))
    g1 = engine.generate_stream([r1])
    g2 = engine.generate_stream([r2])
    got1, got2 = [], []
    alive1 = alive2 = True
    while alive1 or alive2:                       # strict alternation
        if alive1:
            try:
                got1.append(next(g1))
            except StopIteration:
                alive1 = False
        if alive2:
            try:
                got2.append(next(g2))
            except StopIteration:
                alive2 = False
    assert [e.index for e in got1] == list(range(7))
    assert [e.index for e in got2] == list(range(5))
    assert [e.token for e in got1] == r1.generated
    assert [e.token for e in got2] == r2.generated
    assert engine.last_cache.used_pages == 0
    # each stream matches its solo oracle (greedy)
    core, _ = _core(built)
    assert _solo_tokens(core, r1.prompt, SamplingParams(max_new_tokens=7),
                        rid=0) == r1.generated
    assert _solo_tokens(core, r2.prompt, SamplingParams(max_new_tokens=5),
                        rid=1) == r2.generated


def test_never_started_stream_cleans_up(built):
    """Dropping a generate_stream iterator before its first next() must
    still abort the call's queued requests and unregister its routing
    entry -- they already live on the persistent core."""
    import gc
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    engine = ServeEngine(model=model, params=params, cfg=cfg,
                         serve=ServeConfig(max_batch=2, max_seq_len=64,
                                           page_size=16))
    rng = np.random.default_rng(15)
    req = Request(id=0, prompt=rng.integers(0, cfg.vocab_size, size=5),
                  sampling=SamplingParams(max_new_tokens=4))
    gen = engine.generate_stream([req])
    assert engine.core.stats()["waiting"] == 1
    del gen
    gc.collect()
    assert not engine.core.has_work
    assert req.state == ABORTED
    assert engine._stream_subs == []
    # the engine serves normally afterwards
    again = Request(id=1, prompt=req.prompt.copy(),
                    sampling=SamplingParams(max_new_tokens=4))
    assert len(list(engine.generate_stream([again]))) == 4


def test_direct_request_events_survive_wrapper_steps(built):
    """A direct add_request sharing the core with a generate_stream
    drain: the drain's steps may produce the direct request's tokens --
    they land in core.orphan_events instead of vanishing."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    engine = ServeEngine(model=model, params=params, cfg=cfg,
                         serve=ServeConfig(max_batch=3, max_seq_len=96,
                                           page_size=16,
                                           prefill_chunk=16))
    rng = np.random.default_rng(16)
    rid = engine.core.add_request(
        rng.integers(0, cfg.vocab_size, size=5),
        SamplingParams(max_new_tokens=4), request_id=77)
    stream_req = Request(id=0,
                         prompt=rng.integers(0, cfg.vocab_size, size=6),
                         sampling=SamplingParams(max_new_tokens=10))
    list(engine.generate_stream([stream_req]))
    # finish anything the wrapper left running, collecting directly
    direct = []
    while engine.core.has_work:
        direct += engine.core.step()
    mine = [e for e in engine.core.orphan_events
            if e.request_id == rid] + [e for e in direct
                                       if e.request_id == rid]
    done = next(r for r in engine.core.sched.finished if r.id == rid)
    assert [e.token for e in mine] == done.generated
    assert [e.index for e in mine] == list(range(4))


def test_add_request_aliases_stay_greedy(built):
    """The NEW API never inherits the deprecated engine-global knobs:
    add_request with only the legacy aliases gets the greedy default
    SamplingParams, even on a config whose global knobs would sample."""
    core, cfg = _core(built, temperature=1.0, top_k=0)
    rng = np.random.default_rng(14)
    prompt = rng.integers(0, cfg.vocab_size, size=6)
    rid = core.add_request(prompt, max_new_tokens=5, eos_id=100000)
    req = core.requests[rid]
    assert req.sampling.greedy
    assert req.sampling.stop_token_ids == (100000,)
    toks = _drain(core)[rid]
    rid2 = core.add_request(prompt, SamplingParams(max_new_tokens=5))
    assert _drain(core)[rid2] == toks             # bit-identical greedy


def test_abandoned_stream_aborts_without_prefix_cache(built):
    """Abandoning generate_stream mid-run aborts this call's requests on
    the (now unconditionally persistent) core -- no pages leak and the
    next call serves normally, prefix cache or not."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    serve = ServeConfig(max_batch=2, max_seq_len=96, page_size=16,
                        prefill_chunk=16, debug_invariants=True)
    engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab_size, size=20)
    reqs = [Request(id=i, prompt=prompt.copy(),
                    sampling=SamplingParams(max_new_tokens=8))
            for i in range(2)]
    for ev in engine.generate_stream(reqs):
        break                                      # client disconnect
    mgr = engine.last_cache
    assert mgr.used_pages == 0 and not mgr.cow_pending
    assert engine.core.stats()["aborts"] >= 1
    mgr.check_invariants()
    again = Request(id=9, prompt=prompt.copy(),
                    sampling=SamplingParams(max_new_tokens=8))
    ev_tokens = [e.token for e in engine.generate_stream([again])]
    assert len(ev_tokens) == 8 and again.state == FINISHED


# ---------------------------------------------------------------------------
# deprecation contract of the engine-global knobs
# ---------------------------------------------------------------------------

def test_supported_path_emits_no_deprecation_warning(built):
    """Requests carrying SamplingParams never trip the legacy warning,
    even on a ServeConfig that left the old knobs at their defaults."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    engine = ServeEngine(model=model, params=params, cfg=cfg,
                         serve=ServeConfig(max_batch=2, max_seq_len=64,
                                           page_size=16))
    rng = np.random.default_rng(11)
    req = Request(id=0, prompt=rng.integers(0, cfg.vocab_size, size=5),
                  sampling=SamplingParams(max_new_tokens=4))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        list(engine.generate_stream([req]))
    assert req.state == FINISHED


def test_legacy_global_knobs_warn_exactly_once(built):
    """Params-less requests inheriting a changed engine-global
    temperature/top_k warn once per core -- not per request."""
    from repro.serving.engine import ServeEngine
    model, params, cfg = built
    engine = ServeEngine(model=model, params=params, cfg=cfg,
                         serve=ServeConfig(max_batch=2, max_seq_len=64,
                                           page_size=16, top_k=1))
    rng = np.random.default_rng(12)
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab_size, size=5),
                    max_new_tokens=3) for i in range(2)]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        list(engine.generate_stream(reqs))
        more = [Request(id=5, prompt=rng.integers(0, cfg.vocab_size,
                                                  size=4),
                        max_new_tokens=2)]
        list(engine.generate_stream(more))
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and "temperature/top_k" in str(w.message)]
    assert len(dep) == 1
    # the resolved legacy params are greedy (top_k=1), so tokens match
    # the explicit-params path bit for bit
    sp_req = Request(id=7, prompt=reqs[0].prompt.copy(),
                     sampling=SamplingParams(max_new_tokens=3))
    list(engine.generate_stream([sp_req]))
    assert sp_req.generated == reqs[0].generated


def test_orphan_event_drops_are_counted(built):
    """The orphan-event buffer is bounded (dropping the oldest is the
    point), but drops must not be silent: stats() reports how many
    orphaned events were lost past the 4096-entry window."""
    from repro.serving.core import StreamEvent
    core, _ = _core(built, num_pages=13)
    assert core.stats()["orphans_dropped"] == 0
    cap = core.orphan_events.maxlen
    for i in range(cap + 7):
        core.orphan_events.append(StreamEvent(0, i, i, False))
    st = core.stats()
    assert len(core.orphan_events) == cap
    assert st["orphan_events_pending"] == cap
    assert st["orphans_dropped"] == 7
    # the oldest 7 fell off the window; the newest survive in order
    assert core.orphan_events[0].token == 7
    assert core.orphan_events[-1].token == cap + 6
    # reset() starts a fresh buffer and counter
    core.reset()
    assert core.stats()["orphans_dropped"] == 0


# ---------------------------------------------------------------------------
# fault-tolerance satellites: structured rejection, abort idempotency,
# stop strings
# ---------------------------------------------------------------------------

def test_submit_time_structured_rejection(built):
    """A request that can never fit is rejected at submission with a
    structured RequestRejected (a ValueError, so legacy callers keep
    working) -- never a RuntimeError out of a later step().  The engine
    stays clean and keeps serving."""
    from repro.serving.faults import RequestRejected
    core, cfg = _core(built, num_pages=3)         # 2 usable pages = 32 tok
    rng = np.random.default_rng(40)
    sp = SamplingParams(max_new_tokens=6)
    with pytest.raises(RequestRejected, match="pool has 2") as ei:
        core.add_request(rng.integers(0, cfg.vocab_size, size=40),
                         SamplingParams(max_new_tokens=6), request_id=0)
    assert isinstance(ei.value, ValueError)
    assert ei.value.code == "rejected" and ei.value.request_id == 0
    with pytest.raises(RequestRejected, match="max_seq_len"):
        core.add_request(rng.integers(0, cfg.vocab_size, size=90),
                         SamplingParams(max_new_tokens=30), request_id=1)
    assert not core.requests and not core.has_work
    rid = core.add_request(rng.integers(0, cfg.vocab_size, size=5), sp)
    assert len(_drain(core)[rid]) == 6            # engine unpoisoned


def test_double_abort_has_no_side_effects(built):
    """Aborting twice (or aborting a finished id) must not double-free
    pages, double-count aborts, or disturb a co-tenant."""
    core, cfg = _core(built, num_pages=13)
    rng = np.random.default_rng(41)
    sp = SamplingParams(max_new_tokens=6)
    core.add_request(rng.integers(0, cfg.vocab_size, size=8), sp,
                     request_id=0)
    core.add_request(rng.integers(0, cfg.vocab_size, size=8), sp,
                     request_id=1)
    survivor = core.requests[1]
    core.step()
    assert core.abort(0)
    free = core.mgr.free_pages
    for _ in range(3):
        assert not core.abort(0)                  # idempotent, no effect
    assert core.mgr.free_pages == free
    assert core.aborts == 1
    core.mgr.check_invariants()
    _drain(core)
    assert survivor.state == FINISHED and len(survivor.generated) == 6
    assert not core.abort(1)                      # finished: no-op too
    assert core.aborts == 1 and core.mgr.used_pages == 0


def _detok(tokens):
    """Deterministic test detokenizer: token t -> "<t>"."""
    return "".join(f"<{int(t)}>" for t in tokens)


def test_stop_strings_trim_and_span_token_boundary(built):
    """A stop string spanning a token boundary: only tokens wholly
    before the match are ever emitted (the matcher holds back any text
    suffix that could still become a match), the matched suffix is
    trimmed, and the stream ends with a kind="stop" event naming the
    matched string."""
    model, params, cfg = built
    serve = ServeConfig(max_batch=3, max_seq_len=96, page_size=16,
                        prefill_chunk=16, debug_invariants=True,
                        num_pages=13)
    rng = np.random.default_rng(42)
    prompt = rng.integers(0, cfg.vocab_size, size=7)
    sp = SamplingParams(max_new_tokens=6)

    plain = EngineCore(model, params, cfg, serve, detokenize=_detok)
    want = _solo_tokens(plain, prompt, sp)
    assert len(want) == 6
    # a stop string crossing the boundary between generated tokens 1 and
    # 2: the tail of piece 1 plus the head of piece 2
    pieces = [f"<{t}>" for t in want]
    stop = pieces[1][-2:] + pieces[2][:2]
    text = "".join(pieces)
    match = text.find(stop)
    ends = np.cumsum([len(p) for p in pieces])
    exp_emitted = int((ends <= match).sum())
    assert 0 < exp_emitted < 3                    # genuinely mid-stream

    core = EngineCore(model, params, cfg, serve, detokenize=_detok)
    core.add_request(prompt, SamplingParams(max_new_tokens=6,
                                            stop_strings=(stop,)),
                     request_id=0)
    req = core.requests[0]
    events = []
    while core.has_work:
        events += core.step()
    assert req.state == FINISHED and req.stop_matched
    toks = [e for e in events if e.kind == "token"]
    stops = [e for e in events if e.kind == "stop"]
    assert [e.token for e in toks] == want[:exp_emitted]
    assert not toks or not toks[-1].finished      # stop event terminates
    assert len(stops) == 1
    assert stops[0].finished and stops[0].token == -1
    assert stops[0].detail == stop
    assert core.mgr.used_pages == 0
    assert not core._stop_state                   # holdback state freed


def test_stop_strings_holdback_then_flush(built):
    """A stop-string *prefix* at the text tail is held back (never
    half-emit a potential match) but flushed in order when the request
    finishes by length instead."""
    model, params, cfg = built
    serve = ServeConfig(max_batch=3, max_seq_len=96, page_size=16,
                        prefill_chunk=16, debug_invariants=True,
                        num_pages=13)
    rng = np.random.default_rng(43)
    prompt = rng.integers(0, cfg.vocab_size, size=5)
    sp = SamplingParams(max_new_tokens=5)
    plain = EngineCore(model, params, cfg, serve, detokenize=_detok)
    want = _solo_tokens(plain, prompt, sp)
    # piece 2 is a proper prefix of the stop string, which never
    # completes: token 2 must be held while the request is live
    stop = f"<{want[2]}>" + "§never"

    core = EngineCore(model, params, cfg, serve, detokenize=_detok)
    core.add_request(prompt, SamplingParams(max_new_tokens=5,
                                            stop_strings=(stop,)),
                     request_id=0)
    req = core.requests[0]
    held_seen = False
    events = []
    while core.has_work:
        events += core.step()
        if len(req.generated) == 3 and not req.done:
            assert req.emitted == 2, "potential match was half-emitted"
            held_seen = True
    assert held_seen
    toks = [e for e in events if e.kind == "token"]
    assert [e.token for e in toks] == want        # flushed, bit-identical
    assert [e.index for e in toks] == list(range(5))
    assert toks[-1].finished and req.state == FINISHED
    assert not any(e.kind == "stop" for e in events)


def test_stop_strings_require_detokenizer(built):
    from repro.serving.faults import RequestRejected
    core, cfg = _core(built, num_pages=13)        # no detokenize=
    rng = np.random.default_rng(44)
    with pytest.raises(RequestRejected, match="detokenize"):
        core.add_request(rng.integers(0, cfg.vocab_size, size=5),
                         SamplingParams(max_new_tokens=3,
                                        stop_strings=("x",)))
    assert not core.has_work


def test_stop_strings_validation():
    sp = SamplingParams(stop_strings=["ab", "ab", "c"])
    assert sp.stop_strings == ("ab", "c")         # deduped, order kept
    with pytest.raises(ValueError, match="stop_strings"):
        SamplingParams(stop_strings=("ok", ""))
