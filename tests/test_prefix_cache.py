"""Prefix-cache subsystem tests: radix index, refcounts, copy-on-write,
and end-to-end cross-request KV reuse.

Unit level: ``PagedKVCache`` refcounting (share/incref/decref, COW of a
shared partially-filled tail page, invariants with external holds) and
``RadixPrefixIndex`` insert/match/evict semantics (longest page-aligned
match, first-insert-wins on duplicate blocks, LRU leaf eviction that
never frees a page a live slot still references, capacity trimming) --
plus a hypothesis property test driving random traces through the real
cache+index pair against a first-insert-wins oracle.

System level: with ``ServeConfig(prefix_cache=True)`` warm requests
share the cached prefix pages (admission reports ``matched_len``),
chunked prefill skips the matched prefix's launches entirely, a
full-prompt hit recomputes exactly one token through a COW'd tail page,
and greedy tokens stay bit-identical to a cold run -- across chunked
and scan prefill modes, and under a 60%-of-worst-case pool where
preemption and prefix sharing interact.
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.paged_cache import OutOfPages, PagedKVCache
from repro.serving.prefix_cache import RadixPrefixIndex
from repro.serving.pressure import PressureManager
from repro.serving.scheduler import (FINISHED, ContinuousBatchScheduler,
                                     Request)

PS = 4      # page size for the host-side unit tests


def _cache(num_pages=16, max_slots=4, max_pages_per_seq=8):
    return PagedKVCache(num_pages=num_pages, page_size=PS,
                        max_slots=max_slots,
                        max_pages_per_seq=max_pages_per_seq)


# ---------------------------------------------------------------------------
# unit: refcounts + copy-on-write in the page manager
# ---------------------------------------------------------------------------

def test_share_pages_refcounts_and_free_order():
    c = _cache()
    c.alloc(0)
    pages = c.append(0, 2 * PS)                  # 2 full pages
    assert [c.refcount(p) for p in pages] == [1, 1]
    c.alloc(1)
    c.share_pages(1, pages, 2 * PS)
    assert [c.refcount(p) for p in pages] == [2, 2]
    assert c.owned_pages(1) == pages and c.seq_len(1) == 2 * PS
    c.check_invariants()
    free_before = c.free_pages
    c.free(0)                                    # sharer keeps them alive
    assert c.free_pages == free_before
    assert [c.refcount(p) for p in pages] == [1, 1]
    c.check_invariants()
    c.free(1)                                    # last ref: pages return
    assert c.free_pages == free_before + 2
    assert [c.refcount(p) for p in pages] == [0, 0]
    c.check_invariants()


def test_share_pages_validation():
    c = _cache()
    c.alloc(0)
    pages = c.append(0, PS + 1)
    c.alloc(1)
    with pytest.raises(ValueError):
        c.share_pages(1, [], 0)                  # nothing to share
    with pytest.raises(ValueError):
        c.share_pages(1, pages, 2 * PS + 1)      # tokens > capacity
    with pytest.raises(ValueError):
        c.share_pages(1, pages, PS)              # tokens under-use pages
    with pytest.raises(ValueError):
        c.share_pages(1, [c.SCRATCH], 1)         # scratch unshareable
    free = [p for p in range(1, c.num_pages) if c.refcount(p) == 0][0]
    with pytest.raises(ValueError):
        c.share_pages(1, [free], 1)              # free page unshareable
    c.share_pages(1, pages, PS + 1)              # exact length fine
    with pytest.raises(ValueError):
        c.share_pages(1, pages, PS + 1)          # slot no longer empty
    c.check_invariants()


def test_append_cow_on_shared_partial_tail():
    """Appending into a partially-filled tail page that another slot
    shares moves the writer onto a fresh copy: the sharer's page is
    untouched, the (src, dst) pair is recorded for the device copy."""
    c = _cache()
    c.alloc(0)
    pages = c.append(0, PS + 2)                  # tail page partial
    c.alloc(1)
    c.share_pages(1, pages, PS + 2)
    tail = pages[-1]
    assert c.refcount(tail) == 2
    new = c.append(1, 1)                         # writes into the tail
    assert new == []                             # no *extra* page
    assert c.cow_pending and len(c.cow_pending) == 1
    src, dst = c.cow_pending[0]
    assert src == tail and dst != tail
    assert c.owned_pages(1) == [pages[0], dst]
    assert c.table[1, 1] == dst
    assert c.refcount(tail) == 1                 # slot 0's alone again
    assert c.refcount(dst) == 1
    assert c.owned_pages(0) == pages             # sharer untouched
    c.cow_pending.clear()
    c.check_invariants()

    # no COW when the tail is exclusive or the write is page-aligned
    c2 = _cache()
    c2.alloc(0)
    p2 = c2.append(0, PS)                        # aligned: tail full
    c2.alloc(1)
    c2.share_pages(1, p2, PS)
    c2.append(1, 1)                              # next write: fresh page
    assert not c2.cow_pending
    c2.append(1, 1)                              # exclusive partial tail
    assert not c2.cow_pending
    c2.check_invariants()


def test_append_cow_needs_a_free_page():
    c = PagedKVCache(num_pages=3, page_size=PS, max_slots=2,
                     max_pages_per_seq=2)
    c.alloc(0)
    c.append(0, PS + 1)                          # both usable pages
    c.alloc(1)
    c.share_pages(1, c.owned_pages(0), PS + 1)
    with pytest.raises(OutOfPages):
        c.append(1, 1)                           # COW copy has no page
    assert not c.cow_pending                     # failed append: no-op
    assert c.seq_len(1) == PS + 1
    c.check_invariants()


def test_check_invariants_extern_refs_balance():
    c = _cache()
    c.alloc(0)
    [page] = c.append(0, PS)
    c.incref(page)                               # external (index) hold
    c.check_invariants(extern_refs={page: 1})
    with pytest.raises(AssertionError):
        c.check_invariants(extern_refs={})       # unexplained refcount
    c.free(0)
    assert c.refcount(page) == 1                 # survives via the hold
    c.check_invariants(extern_refs={page: 1})
    assert c.decref(page) is True                # last ref: freed
    c.check_invariants(extern_refs={})
    with pytest.raises(ValueError):
        c.decref(page)                           # already free
    with pytest.raises(ValueError):
        c.incref(page)                           # free page un-holdable


# ---------------------------------------------------------------------------
# unit: radix index
# ---------------------------------------------------------------------------

def _toks(*blocks):
    """Build a token array from per-page lists."""
    return np.asarray([t for b in blocks for t in b], np.int32)


def test_index_match_insert_roundtrip():
    c = _cache()
    idx = RadixPrefixIndex(c)
    assert idx.page_size == PS
    c.alloc(0)
    pages = c.append(0, 3 * PS)
    toks = np.arange(3 * PS, dtype=np.int32)
    assert idx.insert(toks, pages) == 3
    assert len(idx) == 3 and idx.cached_pages == 3
    assert [c.refcount(p) for p in pages] == [2, 2, 2]
    c.check_invariants(extern_refs=idx.page_refs())

    # exact, partial (non-aligned tail ignored), diverging, and miss
    assert idx.match(toks) == (pages, 3 * PS)
    assert idx.match(toks[:2 * PS + 1]) == (pages[:2], 2 * PS)
    div = toks.copy()
    div[PS] = 999
    assert idx.match(div) == (pages[:1], PS)
    assert idx.match(toks[1:]) == ([], 0)
    assert idx.match(toks[:PS - 1]) == ([], 0)   # sub-page: no match

    c.free(0)                                    # index keeps pages live
    assert [c.refcount(p) for p in pages] == [1, 1, 1]
    assert idx.match(toks) == (pages, 3 * PS)
    c.check_invariants(extern_refs=idx.page_refs())


def test_index_duplicate_insert_keeps_first_page():
    """Two concurrent cold runs of one prompt produce duplicate blocks:
    the first-published page wins, the newcomer's copy just loses its
    last reference at retire."""
    c = _cache()
    idx = RadixPrefixIndex(c)
    toks = np.arange(2 * PS, dtype=np.int32)
    c.alloc(0)
    first = c.append(0, 2 * PS)
    idx.insert(toks, first)
    c.alloc(1)
    second = c.append(1, 2 * PS)
    assert idx.insert(toks, second) == 0         # nothing new
    assert idx.match(toks) == (first, 2 * PS)
    assert [c.refcount(p) for p in second] == [1, 1]
    c.free(0)
    c.free(1)                                    # duplicates freed
    assert [c.refcount(p) for p in second] == [0, 0]
    assert idx.match(toks) == (first, 2 * PS)
    c.check_invariants(extern_refs=idx.page_refs())


def test_index_lru_leaf_eviction():
    c = _cache(num_pages=32)
    idx = RadixPrefixIndex(c)
    seqs = []
    for i in range(3):
        toks = _toks([i] * PS, [10 + i] * PS)    # distinct 2-block paths
        c.alloc(0)
        pages = c.append(0, 2 * PS)
        idx.insert(toks, pages)
        c.free(0)
        seqs.append((toks, pages))
    free0 = c.free_pages
    # touch sequence 0 so sequence 1 is LRU
    idx.match(seqs[0][0])
    assert idx.evict(1) == 1                     # one page freed...
    assert c.free_pages == free0 + 1
    # ...and it was the LRU path's leaf: seq 1 lost its tail block only
    assert idx.match(seqs[1][0]) == (seqs[1][1][:1], PS)
    assert idx.match(seqs[0][0]) == (seqs[0][1], 2 * PS)
    assert idx.match(seqs[2][0]) == (seqs[2][1], 2 * PS)
    # draining everything unwinds branches back-to-front, nothing leaks
    assert idx.evict(100) == 5
    assert len(idx) == 0 and c.used_pages == 0
    c.check_invariants(extern_refs={})


def test_index_eviction_skips_pages_shared_by_live_slots():
    """Pressure eviction must *free* pages: a leaf whose page a live
    slot still references is not touched (decref'ing it would strip the
    index entry yet free nothing)."""
    c = _cache()
    idx = RadixPrefixIndex(c)
    toks = np.arange(PS, dtype=np.int32)
    c.alloc(0)
    pages = c.append(0, PS)
    idx.insert(toks, pages)
    c.free(0)
    c.alloc(1)
    c.share_pages(1, pages, PS)                  # live sharer
    assert idx.evict(1) == 0                     # nothing freeable
    assert len(idx) == 1                         # entry survives
    c.free(1)
    assert idx.evict(1) == 1                     # now reclaimable
    c.check_invariants(extern_refs=idx.page_refs())


def test_index_capacity_trims_lru():
    c = _cache(num_pages=32)
    idx = RadixPrefixIndex(c, capacity_pages=2)
    c.alloc(0)
    pages = c.append(0, 4 * PS)
    idx.insert(np.arange(4 * PS, dtype=np.int32), pages)
    assert len(idx) == 2                         # trimmed leaf-first
    c.free(0)
    assert [c.refcount(p) for p in pages] == [1, 1, 0, 0]
    c.check_invariants(extern_refs=idx.page_refs())


# ---------------------------------------------------------------------------
# property: random insert/match/evict traces against an oracle
# ---------------------------------------------------------------------------

def _run_prefix_trace(seed: int, steps: int = 40) -> None:
    """Random trace through a real cache+index pair.  Oracle: dict of
    block-path -> first-inserted page (first-insert-wins); after every
    op the pair must agree with it and the pool invariants must hold."""
    rng = np.random.default_rng(seed)
    c = PagedKVCache(num_pages=64, page_size=PS, max_slots=2,
                     max_pages_per_seq=8)
    idx = RadixPrefixIndex(c)
    oracle = {}                                  # path tuple -> page

    def sync_oracle():
        alive = {}
        for node in idx._walk():
            path, n = [], node
            while n.block is not None:
                path.append(n.block)
                n = n.parent
            alive[tuple(reversed(path))] = node.page
        # every surviving node: known to the oracle, same page, and the
        # surviving set is prefix-closed (eviction is leaves-only)
        for path, page in alive.items():
            assert oracle.get(path) == page
            assert len(path) == 1 or path[:-1] in alive
        for path in [p for p in oracle if p not in alive]:
            del oracle[path]

    def rand_tokens():
        n_blocks = int(rng.integers(1, 5))
        return rng.integers(0, 3, size=n_blocks * PS).astype(np.int32)

    inserted = []
    for _ in range(steps):
        op = rng.choice(["insert", "match", "match_known", "evict",
                         "share"])
        if op == "insert":
            toks = rand_tokens()
            try:
                c.alloc(0)
                c.append(0, len(toks))
            except OutOfPages:
                c.free(0)
                continue
            pages = c.owned_pages(0)
            idx.insert(toks, pages)
            for i in range(len(pages)):
                path = tuple(tuple(int(t) for t in toks[j:j + PS])
                             for j in range(0, (i + 1) * PS, PS))
                oracle.setdefault(path, pages[i])
            c.free(0)
            inserted.append(toks)
        elif op == "match" or (op == "match_known" and not inserted):
            toks = rand_tokens()
            pages, m = idx.match(toks)
            assert m == len(pages) * PS
            want = []
            for i in range(len(toks) // PS):
                path = tuple(tuple(int(t) for t in toks[j:j + PS])
                             for j in range(0, (i + 1) * PS, PS))
                if path not in oracle:
                    break
                want.append(oracle[path])
            assert pages == want
        elif op == "match_known":
            toks = inserted[int(rng.integers(0, len(inserted)))]
            pages, m = idx.match(toks)
            # a previously inserted sequence matches fully unless
            # eviction trimmed it
            paths_alive = m // PS
            assert all(c.refcount(p) > 0 for p in pages)
            assert paths_alive <= len(toks) // PS
        elif op == "evict":
            n = int(rng.integers(1, 4))
            free0 = c.free_pages
            freed = idx.evict(n)
            assert c.free_pages == free0 + freed
            sync_oracle()
        else:                                    # share + release
            toks = (inserted[int(rng.integers(0, len(inserted)))]
                    if inserted else rand_tokens())
            pages, m = idx.match(toks)
            if m and not c.is_active(1):
                c.alloc(1)
                c.share_pages(1, pages, m)
                c.free(1)
        c.check_invariants(extern_refs=idx.page_refs())
    idx.evict(10 ** 6)
    for slot in (0, 1):
        if c.is_active(slot):
            c.free(slot)
    assert c.used_pages == 0
    c.check_invariants(extern_refs={})


@pytest.mark.parametrize("seed", range(8))
def test_prefix_trace_random(seed):
    _run_prefix_trace(seed)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_prefix_trace_property(seed):
    _run_prefix_trace(seed)


# ---------------------------------------------------------------------------
# unit: preemption under sharing
# ---------------------------------------------------------------------------

def test_preempt_never_frees_pages_a_sharer_references():
    """A victim holding shared prefix pages only decrefs them; its
    exclusive suffix alone is released (and only that is stash-sized
    for swap)."""
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    c = PagedKVCache(num_pages=16, page_size=PS, max_slots=3,
                     max_pages_per_seq=8)
    idx = RadixPrefixIndex(c)
    sched = ContinuousBatchScheduler(c, admission="optimistic",
                                     watermark_pages=1, prefix_cache=idx)
    serve = ServeConfig(preempt_policy="recompute", page_size=PS)
    pressure = PressureManager(cfg, serve, c, sched, prefix_cache=idx)

    prefix_toks = np.arange(2 * PS, dtype=np.int32)
    a = Request(id=0, prompt=np.concatenate(
        [prefix_toks, np.full(2, 77, np.int32)]), max_new_tokens=2)
    b = Request(id=1, prompt=np.concatenate(
        [prefix_toks, np.full(3, 88, np.int32)]), max_new_tokens=2)
    # seed the index as a retiring sequence would
    c.alloc(0)
    seeded = c.append(0, 2 * PS)
    idx.insert(prefix_toks, seeded)
    c.free(0)

    sched.submit(a)
    sched.submit(b)
    admitted = sched.admit()
    assert [r.matched_len for _, r in admitted] == [2 * PS, 2 * PS]
    assert c.owned_pages(a.slot)[:2] == seeded
    assert c.owned_pages(b.slot)[:2] == seeded
    assert [c.refcount(p) for p in seeded] == [3, 3]
    # both finish their prefill tail into exclusive pages
    for r in (a, b):
        c.append(r.slot, r.prefill_total - r.prefilled)
        r.prefilled = r.prefill_total
    c.check_invariants(extern_refs=idx.page_refs())

    victim = pressure.relieve(pools=None, protect=a.slot)
    # relief prefers reclaiming idle cache pages -- but every index page
    # is shared by live slots here, so it must preempt (newest first)
    assert victim is b
    assert victim.resume_kind == "recompute"
    assert [c.refcount(p) for p in seeded] == [2, 2]   # decref'd only
    assert c.owned_pages(a.slot)[:2] == seeded         # sharer intact
    c.check_invariants(extern_refs=idx.page_refs())

    # resume re-matches the (still cached) prefix instead of recomputing
    [(slot2, res)] = sched.admit()
    assert res is b and res.prefilled == 2 * PS
    assert c.owned_pages(slot2)[:2] == seeded
    c.check_invariants(extern_refs=idx.page_refs())


def test_relieve_prefers_idle_cache_pages_over_preemption():
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    c = PagedKVCache(num_pages=16, page_size=PS, max_slots=2,
                     max_pages_per_seq=8)
    idx = RadixPrefixIndex(c)
    sched = ContinuousBatchScheduler(c, prefix_cache=idx)
    serve = ServeConfig(preempt_policy="recompute", page_size=PS)
    pressure = PressureManager(cfg, serve, c, sched, prefix_cache=idx)
    c.alloc(0)
    pages = c.append(0, PS)
    idx.insert(np.arange(PS, dtype=np.int32), pages)
    c.free(0)                                    # page idle, index-held
    free0 = c.free_pages
    assert pressure.relieve(pools=None) is None  # eviction sufficed
    assert pressure.stats["cache_evictions"] == 1
    assert pressure.stats["preemptions"] == 0
    assert c.free_pages == free0 + 1
    c.check_invariants(extern_refs=idx.page_refs())


def test_reserved_admission_accounts_cow_page():
    """The reserved worst-case reservation must include the +1 COW page
    of a full-prompt hit's shared partial tail -- otherwise 'reserved
    never preempts' can be violated one page short."""
    c = PagedKVCache(num_pages=4, page_size=PS, max_slots=2,
                     max_pages_per_seq=4)
    idx = RadixPrefixIndex(c)
    sched = ContinuousBatchScheduler(c, admission="reserved",
                                     prefix_cache=idx)
    toks = np.arange(2 * PS, dtype=np.int32)
    c.alloc(0)
    idx.insert(toks, c.append(0, 2 * PS))        # slot 0 keeps them live
    full_hit = Request(id=0, prompt=toks.copy(), max_new_tokens=PS)
    sched.submit(full_hit)
    # target = 3*PS -> 3 pages worst, 2 shared; the remaining 1 free
    # page is NOT enough: decode growth needs 1 AND the COW copy needs 1
    # -- and nothing is evictable while slot 0 shares the cached pages
    assert sched.admit() == []
    assert full_hit.matched_len == 0             # still waiting
    c.free(0)                                    # sharer gone: evictable
    # admission trims one LRU leaf to cover the shortfall; the shrunken
    # match (one full page, no partial shared tail) needs no COW page
    [(slot, req)] = sched.admit()
    assert req is full_hit and req.matched_len == PS
    c.check_invariants(extern_refs=idx.page_refs())


def test_blocked_admission_does_not_inflate_stats():
    """A blocked head-of-queue request re-plans its match every admit()
    call; only the consumed match may count in the hit/miss stats."""
    c = PagedKVCache(num_pages=4, page_size=PS, max_slots=2,
                     max_pages_per_seq=3)
    idx = RadixPrefixIndex(c)
    sched = ContinuousBatchScheduler(c, watermark_pages=0,
                                     prefix_cache=idx)
    toks = np.arange(PS, dtype=np.int32)
    c.alloc(0)
    idx.insert(toks, c.append(0, PS))
    # slot 0 stays active holding the other pages: no room for the next
    c.append(0, 2 * PS)
    blocked = Request(id=1, prompt=np.concatenate(
        [toks, np.full(PS, 7, np.int32)]), max_new_tokens=1)
    sched.submit(blocked)
    for _ in range(3):
        assert sched.admit() == []               # pool exhausted
    assert idx.stats["hits"] == idx.stats["misses"] == 0
    c.free(0)
    [(_, req)] = [x for x in sched.admit() if x[1] is blocked]
    assert req.matched_len == PS
    assert idx.stats["hits"] == 1 and idx.stats["hit_tokens"] == PS
    c.check_invariants(extern_refs=idx.page_refs())


# ---------------------------------------------------------------------------
# system: end-to-end sharing through the engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine():
    from repro.models import build_model
    from repro.serving.engine import ServeEngine
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))

    def make(serve):
        return ServeEngine(model=model, params=params, cfg=cfg,
                           serve=serve), cfg
    return make


ENGINE_KW = dict(max_batch=2, max_seq_len=96, top_k=1, page_size=16,
                 prefill_chunk=16, debug_invariants=True)


def _run(engine, reqs):
    events = list(engine.generate_stream(reqs))
    assert all(r.state == FINISHED for r in reqs)
    assert len(events) == sum(r.max_new_tokens for r in reqs)
    return [r.generated for r in reqs]


def _mixed_requests(cfg, sys_prompt, seed, n=3, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(id=i, prompt=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab_size, size=4 + 3 * i)]),
        max_new_tokens=max_new) for i in range(n)]


@pytest.mark.parametrize("mode", ["chunked", "scan"])
def test_warm_requests_share_and_match_cold_tokens(tiny_engine, mode):
    """Hit/miss/partial admission end-to-end: warm requests share the
    page-aligned system-prompt prefix, prefill launches only cover the
    uncached tail, and greedy tokens are bit-identical to a cold
    engine."""
    rng = np.random.default_rng(0)
    engine, cfg = tiny_engine(ServeConfig(prefill_mode=mode, **ENGINE_KW))
    sys_prompt = rng.integers(0, cfg.vocab_size, size=40)   # 2.5 pages
    oracle = _run(engine, _mixed_requests(cfg, sys_prompt, seed=1))

    warm, cfg = tiny_engine(ServeConfig(prefix_cache=True,
                                        prefill_mode=mode, **ENGINE_KW))
    seeds = _mixed_requests(cfg, sys_prompt, seed=9)
    _run(warm, seeds)                            # seed the index
    assert seeds[0].matched_len == 0             # cold start missed
    warm.prefill_launches = 0
    reqs = _mixed_requests(cfg, sys_prompt, seed=1)
    tokens = _run(warm, reqs)
    assert tokens == oracle
    assert all(r.matched_len == 32 for r in reqs)   # aligned 40 -> 32
    if mode == "chunked":
        # the matched prefix cost zero prefill-attention launches: every
        # request's uncached tail fits one 16-token chunk, and chunks of
        # distinct sequences batch -- cold needed >= 3 chunks/request
        assert 0 < warm.prefill_launches <= len(reqs)
    mgr, prefix = warm.last_cache, warm.last_prefix
    mgr.check_invariants(extern_refs=prefix.page_refs())
    assert mgr.used_pages == prefix.cached_pages > 0
    assert prefix.stats["hit_tokens"] >= 32 * len(reqs)


def test_full_prompt_hit_cow_divergence(tiny_engine):
    """A fully-cached page-aligned prompt keeps every page shared and
    recomputes exactly one token; the write COW-copies the shared tail
    page, so the cached copy serves later requests unchanged."""
    rng = np.random.default_rng(3)
    engine, cfg = tiny_engine(ServeConfig(**ENGINE_KW))
    prompt = rng.integers(0, cfg.vocab_size, size=32)       # 2 pages
    [oracle] = _run(engine, [Request(id=0, prompt=prompt,
                                     max_new_tokens=6)])

    warm, cfg = tiny_engine(ServeConfig(prefix_cache=True, **ENGINE_KW))
    _run(warm, [Request(id=1, prompt=prompt, max_new_tokens=6)])
    warm.prefill_launches = 0
    for rep in range(2, 4):                      # hit the COW path twice
        [req] = [Request(id=rep, prompt=prompt, max_new_tokens=6)]
        assert _run(warm, [req]) == [oracle]
        assert req.matched_len == 31             # all but the last token
        assert len(req.prompt) - req.matched_len == 1
    assert warm.prefill_launches == 2            # one 1-token chunk each
    warm.last_cache.check_invariants(
        extern_refs=warm.last_prefix.page_refs())


def test_multi_turn_extension_matches_generated_blocks(tiny_engine):
    """A follow-up prompt that extends prompt+completion (a multi-turn
    round trip) matches into the blocks the first turn *generated*."""
    rng = np.random.default_rng(5)
    warm, cfg = tiny_engine(ServeConfig(prefix_cache=True, **ENGINE_KW))
    first = Request(id=0, prompt=rng.integers(0, cfg.vocab_size, size=30),
                    max_new_tokens=8)
    _run(warm, [first])
    # materialised KV at retire: 30 + 8 - 1 = 37 tokens -> 2 full pages
    follow_prompt = np.concatenate(
        [first.prompt, np.asarray(first.generated, np.int32),
         rng.integers(0, cfg.vocab_size, size=6)])
    follow = Request(id=1, prompt=follow_prompt, max_new_tokens=4)
    _run(warm, [follow])
    assert follow.matched_len == 32              # past the prompt's 30

    cold, cfg = tiny_engine(ServeConfig(**ENGINE_KW))
    oracle = Request(id=2, prompt=follow_prompt, max_new_tokens=4)
    _run(cold, [oracle])
    assert follow.generated == oracle.generated


def test_lru_eviction_under_pool_pressure(tiny_engine):
    """A pool too small to cache every retired prompt forces LRU leaf
    evictions (admission-time and OutOfPages-time) -- requests all
    complete and the pool never leaks."""
    rng = np.random.default_rng(6)
    kw = dict(ENGINE_KW, num_pages=10)           # 9 usable pages
    engine, cfg = tiny_engine(ServeConfig(prefix_cache=True, **kw))
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab_size,
                                              size=40 + i),
                    max_new_tokens=12) for i in range(4)]
    _run(engine, reqs)
    prefix, pressure = engine.last_prefix, engine.last_pressure
    assert prefix.stats["evicted_blocks"] > 0, "pool never pressured"
    mgr = engine.last_cache
    mgr.check_invariants(extern_refs=prefix.page_refs())
    assert mgr.used_pages == prefix.cached_pages
    assert mgr.used_pages <= 9
    assert pressure.stats["preemptions"] >= 0    # may or may not preempt


def test_abandoned_stream_leaves_persistent_state_clean(tiny_engine):
    """Breaking out of a generate_stream mid-run (client disconnect)
    must not wedge the persistent prefix-cache state: the abandoned
    stream's slots are reconciled and the next call serves normally."""
    rng = np.random.default_rng(12)
    engine, cfg = tiny_engine(ServeConfig(prefix_cache=True, **ENGINE_KW))
    prompt = rng.integers(0, cfg.vocab_size, size=34)
    reqs = [Request(id=i, prompt=prompt.copy(), max_new_tokens=8)
            for i in range(2)]
    for ev in engine.generate_stream(reqs):
        break                                    # abandon after 1 token
    mgr = engine.last_cache
    assert all(not mgr.is_active(s) for s in range(mgr.max_slots))
    assert not mgr.cow_pending
    mgr.check_invariants(extern_refs=engine.last_prefix.page_refs())

    cold, cfg = tiny_engine(ServeConfig(**ENGINE_KW))
    oracle = Request(id=9, prompt=prompt.copy(), max_new_tokens=8)
    _run(cold, [oracle])
    again = Request(id=3, prompt=prompt.copy(), max_new_tokens=8)
    _run(engine, [again])                        # same engine, clean run
    assert again.generated == oracle.generated
    mgr.check_invariants(extern_refs=engine.last_prefix.page_refs())


@pytest.mark.parametrize("policy", ["swap", "recompute"])
def test_sharing_under_preemption_bit_identical(tiny_engine, policy):
    """Shared system prompt + a pool at ~60% of worst-case demand: the
    prefix cache, COW, preemption and swap interact, every request
    completes, no shared page is freed from under a sharer (invariants
    every step), and greedy tokens match the unpressured cold run."""
    rng = np.random.default_rng(8)
    engine, cfg = tiny_engine(ServeConfig(**ENGINE_KW))
    sys_prompt = rng.integers(0, cfg.vocab_size, size=32)
    spec = [(6, 20), (3, 26), (9, 18), (5, 24)]
    def make():
        r = np.random.default_rng(11)
        return [Request(id=i, prompt=np.concatenate(
            [sys_prompt, r.integers(0, cfg.vocab_size, size=s)]),
            max_new_tokens=n) for i, (s, n) in enumerate(spec)]
    oracle = _run(engine, make())

    # 5 usable pages vs a 16-page realised worst case (~31%): tight
    # enough that index eviction alone cannot absorb the pressure (the
    # cached pages are mostly shared by live slots) and decode growth
    # must preempt
    pool = 6
    kw = dict(ENGINE_KW, num_pages=pool, preempt_policy=policy)
    pressured, cfg = tiny_engine(ServeConfig(prefix_cache=True, **kw))
    _run(pressured, make())                      # seed (under pressure!)
    tokens = _run(pressured, make())             # warm, still pressured
    assert tokens == oracle
    mgr, prefix = pressured.last_cache, pressured.last_prefix
    pressure = pressured.last_pressure
    assert pressure.stats["preemptions"] > 0, "pool never pressured"
    assert prefix.stats["evicted_blocks"] > 0, "index never trimmed"
    if policy == "swap":
        assert pressure.stats["swaps"] > 0
    assert mgr.peak_used_pages <= pool - 1
    assert len(pressure.host_pool) == 0, "stash leaked"
    mgr.check_invariants(extern_refs=prefix.page_refs())
    assert mgr.used_pages == prefix.cached_pages
