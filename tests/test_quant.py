"""Weight-only int8: error bounds + end-to-end orthogonality (paper D.2)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.config import ParallelConfig, get_model_config, reduce_for_smoke
from repro.models import build_model
from repro.quant.int8 import (dequantize_tree, quantize_tensor,
                              quantize_tree, quantized_size_bytes)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), rows=st.integers(1, 64),
       cols=st.integers(1, 64))
def test_per_channel_error_bound(seed, rows, cols):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)) *
                    rng.uniform(0.01, 10), jnp.float32)
    qt = quantize_tensor(w)
    wd = (qt.q.astype(jnp.float32) * qt.scale)
    # symmetric per-channel: |err| <= scale/2 per element
    err = np.abs(np.asarray(wd - w))
    bound = np.asarray(qt.scale) / 2 + 1e-9
    assert (err <= np.broadcast_to(bound, err.shape) + 1e-7).all()


def test_e2e_orthogonality_logit_drift():
    """Paper D.2: quantization composes with FastAttention.  int8 weights
    must not change greedy decisions on a smoke model."""
    cfg = reduce_for_smoke(get_model_config("llama2-7b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    base = model.apply(params, toks).astype(jnp.float32)
    qparams = quantize_tree(params)
    deq = dequantize_tree(qparams, dtype=jnp.float32)
    quant = model.apply(deq, toks).astype(jnp.float32)
    # bounded drift + identical greedy tokens
    rel = float(jnp.max(jnp.abs(quant - base)) /
                jnp.maximum(jnp.max(jnp.abs(base)), 1e-9))
    assert rel < 0.15, rel
    agree = float(jnp.mean((jnp.argmax(quant, -1) ==
                            jnp.argmax(base, -1)).astype(jnp.float32)))
    assert agree > 0.95, agree
    # ~2x weight compression (int8 + f32 scales vs f32)
    orig = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    assert quantized_size_bytes(qparams) < 0.6 * orig
