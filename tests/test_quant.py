"""Weight-only int8: error bounds + end-to-end orthogonality (paper D.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.config import ParallelConfig, get_model_config, reduce_for_smoke
from repro.models import build_model
from repro.quant.int8 import (dequantize_tree, quantize_tensor,
                              quantize_tree, quantized_size_bytes)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), rows=st.integers(1, 64),
       cols=st.integers(1, 64))
def test_per_channel_error_bound(seed, rows, cols):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)) *
                    rng.uniform(0.01, 10), jnp.float32)
    qt = quantize_tensor(w)
    wd = (qt.q.astype(jnp.float32) * qt.scale)
    # symmetric per-channel: |err| <= scale/2 per element
    err = np.abs(np.asarray(wd - w))
    bound = np.asarray(qt.scale) / 2 + 1e-9
    assert (err <= np.broadcast_to(bound, err.shape) + 1e-7).all()


def test_e2e_orthogonality_logit_drift():
    """Paper D.2: quantization composes with FastAttention.  int8 weights
    must not change greedy decisions on a smoke model."""
    cfg = reduce_for_smoke(get_model_config("llama2-7b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    base = model.apply(params, toks).astype(jnp.float32)
    qparams = quantize_tree(params)
    deq = dequantize_tree(qparams, dtype=jnp.float32)
    quant = model.apply(deq, toks).astype(jnp.float32)
    # bounded drift + identical greedy tokens wherever greedy is decisive
    # (at near-tie positions -- margin below the quantization noise --
    # argmax of a random-init model is a coin flip, not a property)
    rel = float(jnp.max(jnp.abs(quant - base)) /
                jnp.maximum(jnp.max(jnp.abs(base)), 1e-9))
    assert rel < 0.15, rel
    agree = jnp.argmax(quant, -1) == jnp.argmax(base, -1)
    err = jnp.max(jnp.abs(quant - base))
    top2 = jax.lax.top_k(base, 2)[0]
    decisive = (top2[..., 0] - top2[..., 1]) > 2 * err
    assert float(jnp.mean(decisive.astype(jnp.float32))) > 0.1
    assert bool(jnp.all(agree[decisive]))
    assert float(jnp.mean(agree.astype(jnp.float32))) > 0.8
    # ~2x weight compression (int8 + f32 scales vs f32)
    orig = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    assert quantized_size_bytes(qparams) < 0.6 * orig
