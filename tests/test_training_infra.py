"""Checkpointing, fault tolerance, data pipeline, tiling planner, HLO
parser -- framework-substrate unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import ParallelConfig
from repro.core.tiling import (plan_two_level_tiling, sync_count,
                               vmem_working_set)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import (CadenceController,
                                            HeartbeatMonitor,
                                            StragglerDetector, elastic_plan)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    mgr.save(7, tree, extras={"data": {"step": 7}})
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 7
    assert manifest["extras"]["data"]["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    mgr.save(1, tree, async_=True)
    mgr.wait()
    restored, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(1000, dtype=np.float32))
    # no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]


# --------------------------------------------------------------------------
# fault tolerance / elasticity
# --------------------------------------------------------------------------

def test_heartbeat_detects_dead_hosts():
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10)
    now = 1000.0
    for h in ("h0", "h1", "h2"):
        mon.beat(h, t=now)
    mon.beat("h0", t=now + 20)
    mon.beat("h1", t=now + 20)
    assert mon.dead_hosts(now=now + 21) == ["h2"]
    assert set(mon.alive_hosts(now=now + 21)) == {"h0", "h1"}


def test_straggler_detector():
    det = StragglerDetector(k=3.0)
    for step in range(10):
        for h in range(8):
            det.record(f"h{h}", 1.0 + 0.01 * h)
        det.record("h_slow", 5.0)
    assert det.stragglers() == ["h_slow"]


@settings(max_examples=60, deadline=None)
@given(alive=st.integers(16, 512))
def test_elastic_plan_always_forms_legal_mesh(alive):
    p = ParallelConfig(pods=2, data=16, model=16)
    try:
        q = elastic_plan(p, alive)
    except RuntimeError:
        assert alive < 16  # can't go below one model group
        return
    assert q.pods * q.data * q.model <= alive
    assert q.model == p.model          # weight shards preserved


def test_cadence_controller_tightens_on_failures():
    c = CadenceController(budget_steps=10)
    c.record_steps(100)
    loose = c.cadence()
    assert loose == c.max_cadence          # no failures -> loosest cadence
    for _ in range(10):                    # lambda = 0.1/step
        c.record_failure()
    tight = c.cadence()
    assert tight < loose
    assert tight == 200                    # 2 * budget / lambda


def test_elastic_restore_reshards(tmp_path):
    """Checkpoint written under one layout restores under another."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, tree)
    restored, _ = mgr.restore(tree, shardings=jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
        tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_pipeline_determinism_and_resume():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4)
    p1 = TokenPipeline(cfg)
    batches = [p1.next() for _ in range(5)]
    p2 = TokenPipeline(cfg)
    p2.restore({"step": 3})
    b3 = p2.next()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    assert (batches[0]["tokens"][:, 1:] == batches[0]["labels"][:, :-1]).all()


def test_pipeline_host_sharding_disjoint():
    cfg0 = DataConfig(vocab_size=1000, seq_len=8, global_batch=8,
                      host_count=2, host_index=0)
    cfg1 = DataConfig(vocab_size=1000, seq_len=8, global_batch=8,
                      host_count=2, host_index=1)
    b0 = TokenPipeline(cfg0).next()
    b1 = TokenPipeline(cfg1).next()
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# --------------------------------------------------------------------------
# two-level tiling planner (T1)
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(seq=st.integers(128, 1 << 19), d=st.sampled_from([64, 96, 128, 256]))
def test_tiling_plan_fits_budget(seq, d):
    plan = plan_two_level_tiling(seq, seq, d)
    assert plan.vmem_bytes <= 64 * 1024 * 1024
    assert plan.block_kv1 % plan.block_kv2 == 0
    assert plan.block_kv2 % 128 == 0
    assert plan.m_mask >= max(plan.block_q, plan.block_kv2)


def test_level1_reduces_synchronizations():
    """Paper Fig. 9 mechanism: larger level-1 blocks -> fewer syncs."""
    small = sync_count(16384, 128)
    plan = plan_two_level_tiling(16384, 16384, 128)
    large = sync_count(16384, plan.block_kv1)
    assert large * 4 <= small
    assert plan.block_kv1 > 128


# --------------------------------------------------------------------------
# HLO parser
# --------------------------------------------------------------------------

def test_hlo_parser_matches_builtin_on_scanfree():
    from repro.analysis.hlo import analyze_hlo_text
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731

    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    c = jax.jit(f).lower(sds(128, 256), sds(256, 512), sds(512, 64)
                         ).compile()
    mine = analyze_hlo_text(c.as_text()).flops
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax returns [dict]
        ca = ca[0]
    builtin = ca["flops"]
    assert abs(mine - builtin) / builtin < 0.05


def test_hlo_parser_multiplies_scan_trip_counts():
    from repro.analysis.hlo import analyze_hlo_text
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731

    def g(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]

    c = jax.jit(g).lower(sds(256, 256), sds(256, 256)).compile()
    mine = analyze_hlo_text(c.as_text()).flops
    expect = 10 * (2 * 256 ** 3 + 256 * 256)
    assert abs(mine - expect) / expect < 0.05
