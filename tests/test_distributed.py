"""Distribution correctness on an 8-device child process mesh.

These spawn subprocesses with XLA_FLAGS=8 fake devices so the main pytest
process keeps its single-device view (per the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


CHILD_PRELUDE = """
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.core.compat import shard_map
"""


def test_tiled_allreduce_variants_match():
    r = run_child(CHILD_PRELUDE + """
import functools
from repro.core.tiled_allreduce import (tiled_matmul_allreduce,
    single_matmul_allreduce, ring_matmul_allreduce,
    tiled_matmul_reducescatter)
mesh = make_mesh((2,4), ('data','model'))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
ref = x @ w
errs = {}
for name, fn in [('single', single_matmul_allreduce),
                 ('tiled', tiled_matmul_allreduce),
                 ('ring', ring_matmul_allreduce)]:
    f = shard_map(functools.partial(fn, axis_name='model'), mesh=mesh,
        in_specs=(P(None,'model'), P('model',None)),
        out_specs=P(None,None), check_vma=False)
    errs[name] = float(jnp.max(jnp.abs(jax.jit(f)(x, w) - ref)))
# reduce-scatter variant: rows come back chunk-block-scattered, so
# compare with n_chunks=1 where the global ordering is the identity
f = shard_map(functools.partial(tiled_matmul_reducescatter,
    axis_name='model', n_chunks=1), mesh=mesh,
    in_specs=(P(None,'model'), P('model',None)),
    out_specs=P('model',None), check_vma=False)
errs['rs'] = float(jnp.max(jnp.abs(jax.jit(f)(x, w) - ref)))
print(json.dumps(errs))
""")
    for name, err in r.items():
        assert err < 1e-4, (name, err)


def test_tiled_allreduce_emits_multiple_collectives():
    """T3 structure check: tiled mode has n_chunks collectives vs 1."""
    r = run_child(CHILD_PRELUDE + """
import functools
from repro.core.tiled_allreduce import (ring_matmul_allreduce,
                                        single_matmul_allreduce)
from repro.analysis.hlo import analyze_hlo_text
mesh = make_mesh((8,), ('model',))
sds = jax.ShapeDtypeStruct
counts = {}
for name, fn, kw in [('single', single_matmul_allreduce, {}),
                     ('ring', ring_matmul_allreduce, dict(n_chunks=4))]:
    f = shard_map(functools.partial(fn, axis_name='model', **kw),
        mesh=mesh, in_specs=(P(None,'model'), P('model',None)),
        out_specs=P(None,None), check_vma=False)
    c = jax.jit(f).lower(sds((128, 64), jnp.float32),
                         sds((64, 32), jnp.float32)).compile()
    cost = analyze_hlo_text(c.as_text())
    n = sum(n for _, _, n in cost.top_collectives)
    counts[name] = n
print(json.dumps(counts))
""")
    # NOTE: XLA's all-reduce combiner merges adjacent small psums, so the
    # plain `tiled` mode can collapse back to one op at toy sizes; the
    # ring variant's collective-permutes are structurally un-mergeable
    # (data dependence through the accumulator), guaranteeing overlap.
    assert r["single"] >= 1
    assert r["ring"] >= 4 * r["single"]


def test_context_parallel_decode_matches_oracle():
    r = run_child(CHILD_PRELUDE + """
from repro.core.distributed_decode import context_parallel_decode
from repro.kernels.fastattn.ref import decode_reference
mesh = make_mesh((2,4), ('data','model'))
rng = np.random.default_rng(0)
B,Hq,Hkv,S,D = 4, 8, 2, 256, 32
q = jnp.asarray(rng.normal(size=(B,Hq,1,D)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B,Hkv,S,D)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B,Hkv,S,D)), jnp.float32)
kvlen = jnp.asarray([256, 100, 7, 200], jnp.int32)
errs = {}
ref = decode_reference(q,k,v,kvlen)[:,:,0]
out = context_parallel_decode(mesh, q[:,:,0], k, v, kvlen)
errs['plain'] = float(jnp.max(jnp.abs(out-ref)))
ref2 = decode_reference(q,k,v,kvlen,window=64)[:,:,0]
out2 = context_parallel_decode(mesh, q[:,:,0], k, v, kvlen, window=64)
errs['window'] = float(jnp.max(jnp.abs(out2-ref2)))
print(json.dumps(errs))
""")
    for name, err in r.items():
        assert err < 1e-4, (name, err)


def test_sharded_model_forward_matches_single_device():
    """A reduced arch under the production rule table on a (2,4) mesh must
    produce the same logits as unsharded execution."""
    r = run_child(CHILD_PRELUDE + """
from repro.config import get_model_config, reduce_for_smoke, ParallelConfig
from repro.models import build_model
from repro.sharding.rules import axis_rules, param_sharding_tree
cfg = reduce_for_smoke(get_model_config('qwen2.5-32b'))
mesh = make_mesh((2,4), ('data','model'))
model = build_model(cfg, ParallelConfig(data=2, model=4, remat='none'))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                          cfg.vocab_size)
params = model.init(jax.random.PRNGKey(0))
base = model.apply(params, toks)           # single-device semantics
with axis_rules(mesh=mesh):
    sh = param_sharding_tree(model.logical(), mesh)
    params_s = jax.device_put(params, sh)
    with mesh:
        out = jax.jit(model.apply)(params_s, toks)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                            - base.astype(jnp.float32))))
print(json.dumps({'err': err}))
""")
    assert r["err"] < 1e-3


def test_compressed_psum_error_feedback():
    """int8+EF all-reduce: one-step error bounded, residual carries the
    quantization error so the running average converges."""
    r = run_child(CHILD_PRELUDE + """
from repro.training.compression import compressed_psum
mesh = make_mesh((8,), ('data',))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.normal(size=(8, 64, 32)), jnp.float32)
true_mean = jnp.mean(g_all, axis=0)

def body(g):
    g = g[0]                                 # (64, 32) local shard
    res = jnp.zeros_like(g)
    red, res = compressed_psum(g, res, 'data')
    return red[None], res[None]

f = jax.jit(shard_map(body, mesh=mesh,
    in_specs=P('data', None, None),
    out_specs=(P(None, None, None), P('data', None, None)),
    check_vma=False))
red, res = f(g_all)
rel = float(jnp.max(jnp.abs(red[0] - true_mean))) / \
    float(jnp.max(jnp.abs(true_mean)))
# EF invariant: applied + residual == exact (per device, pre-reduction)
print(json.dumps({'rel': rel}))
""")
    assert r["rel"] < 0.15   # one-shot int8 error (EF recovers it over steps)


def test_chunk_sizes_alignment_contract():
    """Every chunk -- including the trailing remainder -- must respect
    ``align``.  The old code appended a raw remainder, e.g.
    chunk_sizes(10, 2, 1.0, 4) -> [4, 6]: the 6 mis-split the ring's
    per-device pieces and psum_scatter's axis chunks."""
    from repro.core.tiled_allreduce import chunk_sizes

    # the regression shape now refuses instead of mis-aligning
    with pytest.raises(ValueError):
        chunk_sizes(10, 2, 1.0, align=4)
    with pytest.raises(ValueError):
        chunk_sizes(0, 4)
    for total, n, frac, align in [(16, 2, 1.0, 4), (64, 4, 0.5, 8),
                                  (8, 4, 0.5, 4), (128, 4, 0.5, 1),
                                  (12, 5, 0.25, 4), (4, 4, 0.5, 4),
                                  (96, 3, 0.5, 32)]:
        sizes = chunk_sizes(total, n, frac, align=align)
        assert sum(sizes) == total, (total, n, frac, align, sizes)
        assert all(s > 0 for s in sizes), sizes
        assert all(s % align == 0 for s in sizes), (align, sizes)
        assert len(sizes) <= n
    # first-chunk shrinking still happens when there is room
    sizes = chunk_sizes(128, 4, 0.5, align=1)
    assert sizes[0] < sizes[1]


def test_allreduce_variants_match_on_unaligned_rows():
    """Equivalence on row counts that divide NEITHER the chunk count nor
    the axis size, across 2- and 4-way meshes: the ring variant pads to
    a multiple of the axis size internally and slices the pad back off;
    the reduce-scatter variant refuses rather than mis-splitting."""
    r = run_child(CHILD_PRELUDE + """
import functools
from repro.core.tiled_allreduce import (tiled_matmul_allreduce,
    single_matmul_allreduce, ring_matmul_allreduce,
    tiled_matmul_reducescatter, matmul_allreduce)
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
errs = {}
for ways in (2, 4):
    mesh = make_mesh((ways,), ('model',))
    for t in (37, 50):
        x = jnp.asarray(rng.normal(size=(t, 32)), jnp.float32)
        ref = x @ w
        for name, fn in [('single', single_matmul_allreduce),
                         ('tiled', tiled_matmul_allreduce),
                         ('ring', ring_matmul_allreduce)]:
            f = shard_map(functools.partial(fn, axis_name='model'),
                mesh=mesh, in_specs=(P(None,'model'), P('model',None)),
                out_specs=P(None,None), check_vma=False)
            errs[f'{name}-{ways}w-{t}'] = float(jnp.max(jnp.abs(
                jax.jit(f)(x, w) - ref)))
        # dispatcher parity on the same shapes
        for mode in ('tiled', 'single'):
            f = shard_map(functools.partial(matmul_allreduce,
                axis_name='model', mode=mode), mesh=mesh,
                in_specs=(P(None,'model'), P('model',None)),
                out_specs=P(None,None), check_vma=False)
            errs[f'dispatch-{mode}-{ways}w-{t}'] = float(jnp.max(jnp.abs(
                jax.jit(f)(x, w) - ref)))
    # reduce-scatter refuses axis-indivisible rows instead of corrupting
    x = jnp.asarray(rng.normal(size=(37, 32)), jnp.float32)
    f = shard_map(functools.partial(tiled_matmul_reducescatter,
        axis_name='model'), mesh=mesh,
        in_specs=(P(None,'model'), P('model',None)),
        out_specs=P('model',None), check_vma=False)
    try:
        jax.jit(f)(x, w)
        errs[f'rs-guard-{ways}w'] = 1e9
    except ValueError:
        errs[f'rs-guard-{ways}w'] = 0.0
print(json.dumps(errs))
""")
    for name, err in r.items():
        assert err < 1e-4, (name, err)
