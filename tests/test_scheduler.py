"""Continuous-batching scheduler tests.

Unit level: admit/retire mechanics against the page pool (FIFO order,
worst-case reservation, slot refill, page reclamation).  System level:
sequences finishing at different lengths retire individually, freed slots
are refilled from the waiting queue, and every request's tokens match
per-request single-batch generation (greedy) -- batch composition must
not change what any sequence decodes.
"""
import jax
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import (FINISHED, PREFILLING, RUNNING, WAITING,
                                     ContinuousBatchScheduler, Request)


def _req(i, prompt_len, max_new, rng=None, vocab=256):
    rng = rng or np.random.default_rng(i)
    return Request(id=i, prompt=rng.integers(0, vocab, size=prompt_len),
                   max_new_tokens=max_new)


# ---------------------------------------------------------------------------
# unit: scheduler vs page pool
# ---------------------------------------------------------------------------

def test_admit_fifo_and_slot_assignment():
    cache = PagedKVCache(num_pages=64, page_size=4, max_slots=2,
                         max_pages_per_seq=8)
    sched = ContinuousBatchScheduler(cache)
    reqs = [_req(i, 4, 4) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    admitted = sched.admit()
    assert [(s, r.id) for s, r in admitted] == [(0, 0), (1, 1)]
    # admission enters the chunked-prefill state; the engine flips to
    # RUNNING once the whole prompt is in the cache
    assert reqs[0].state == PREFILLING and reqs[2].state == WAITING
    assert sched.admit() == []                   # no free slot

    # finishing request 0 frees its slot; request 2 takes it
    reqs[0].generated = [1, 2, 3, 4]
    cache.append(0, 4)                           # its prompt pages
    retired = sched.retire()
    assert retired == [reqs[0]] and reqs[0].state == FINISHED
    assert cache.used_pages == 0
    admitted = sched.admit()
    assert [(s, r.id) for s, r in admitted] == [(0, 2)]


def test_reserved_admission_respects_worst_case():
    """The PR 1 baseline policy (admission="reserved") still gates on
    prompt + max_new_tokens worst case."""
    # 7 usable pages of 4 tokens; each request worst-cases 4 pages
    cache = PagedKVCache(num_pages=8, page_size=4, max_slots=4,
                         max_pages_per_seq=4)
    sched = ContinuousBatchScheduler(cache, admission="reserved")
    for i in range(3):
        sched.submit(_req(i, 8, 8))              # target_len 16 = 4 pages
    admitted = sched.admit()
    # only one fits: 2 would reserve 8 > 7 free pages
    assert [r.id for _, r in admitted] == [0]
    # ...even though no physical page is allocated yet
    assert cache.used_pages == 0
    r0 = admitted[0][1]
    r0.generated = list(range(8))
    cache.check_invariants()
    sched.retire()
    assert [r.id for _, r in sched.admit()] == [1]
    cache.check_invariants()

    # same-round admissions must not be double-counted (once via the
    # live slot, once via the promised pages): 8 usable pages fit two
    # 4-page reservations in ONE admit() call
    cache2 = PagedKVCache(num_pages=9, page_size=4, max_slots=4,
                          max_pages_per_seq=4)
    sched2 = ContinuousBatchScheduler(cache2, admission="reserved")
    for i in range(3):
        sched2.submit(_req(i, 8, 8))
    assert [r.id for _, r in sched2.admit()] == [0, 1]


def test_optimistic_admission_gates_on_prompt_and_watermark():
    """Optimistic admission ignores max_new_tokens: a request enters as
    soon as its *prompt* pages fit beside the watermark reserve."""
    cache = PagedKVCache(num_pages=8, page_size=4, max_slots=4,
                         max_pages_per_seq=4)
    sched = ContinuousBatchScheduler(cache, admission="optimistic",
                                     watermark_pages=1)
    for i in range(4):
        sched.submit(_req(i, 8, 8))              # prompt 8 = 2 pages each
    admitted = sched.admit()
    # worst case would admit one; prompts of three fit: 3*2 = 6 <= 7-1
    assert [r.id for _, r in admitted] == [0, 1, 2]
    assert cache.used_pages == 0                 # still lazily allocated
    cache.check_invariants()

    # watermark: with 2 free pages beyond promised, a fourth 2-page
    # prompt would leave less than the 1-page reserve... it fits exactly
    # at the boundary check: 6 promised + 2 = 8 > 7 - 1, so it waits
    assert sched.slots[3] is None
    # ...but a watermark is waived when the grid is empty (progress)
    cache2 = PagedKVCache(num_pages=4, page_size=4, max_slots=2,
                          max_pages_per_seq=4)
    sched2 = ContinuousBatchScheduler(cache2, admission="optimistic",
                                      watermark_pages=3)
    sched2.submit(_req(9, 8, 4))                 # 2-page prompt, 3 usable
    assert [r.id for _, r in sched2.admit()] == [9]


def test_oversized_request_rejected_at_submit():
    cache = PagedKVCache(num_pages=4, page_size=4, max_slots=2,
                         max_pages_per_seq=16)
    sched = ContinuousBatchScheduler(cache)
    with pytest.raises(ValueError, match="pool"):
        sched.submit(_req(0, 30, 10))            # 10 pages > 3 usable
    cache2 = PagedKVCache(num_pages=64, page_size=4, max_slots=2,
                          max_pages_per_seq=2)
    sched2 = ContinuousBatchScheduler(cache2)
    with pytest.raises(ValueError, match="max_seq_len"):
        sched2.submit(_req(0, 8, 4))


def test_prefill_schedule_budget_and_order():
    """Chunk planning: admission order, token budget, >= 1 chunk per step
    even when the budget is smaller than a chunk."""
    cache = PagedKVCache(num_pages=64, page_size=4, max_slots=3,
                         max_pages_per_seq=16)
    sched = ContinuousBatchScheduler(cache)
    a, b = _req(0, 10, 2), _req(1, 7, 2)
    sched.submit(a)
    sched.submit(b)
    sched.admit()

    # budget 8, chunk 4: two chunks of the oldest prompt, nothing of b
    jobs = sched.prefill_schedule(budget=8, chunk=4)
    assert [(r.id, s, n) for _, r, s, n in jobs] == [(0, 0, 4), (0, 4, 4)]
    a.prefilled = 8                              # engine ran the chunks
    # next step: a's 2-token tail, then b's chunks until the budget trips
    jobs = sched.prefill_schedule(budget=8, chunk=4)
    assert [(r.id, s, n) for _, r, s, n in jobs] == \
        [(0, 8, 2), (1, 0, 4), (1, 4, 3)]
    a.prefilled = 10
    a.state = RUNNING
    # zero budget still makes progress (one chunk minimum)
    jobs = sched.prefill_schedule(budget=0, chunk=4)
    assert [(r.id, s, n) for _, r, s, n in jobs] == [(1, 0, 4)]
    b.prefilled = 7
    b.state = RUNNING
    assert sched.prefill_schedule(budget=8, chunk=4) == []


def test_eos_finishes_early():
    r = Request(id=0, prompt=np.array([1, 2]), max_new_tokens=100,
                eos_id=7)
    assert not r.done
    r.generated = [3, 4]
    assert not r.done
    r.generated = [3, 7]
    assert r.done


# ---------------------------------------------------------------------------
# system: continuous batching through the ServeEngine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine():
    from repro.models import build_model
    from repro.serving.engine import ServeEngine
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))

    def make(serve):
        return ServeEngine(model=model, params=params, cfg=cfg,
                           serve=serve), cfg
    return make


def test_continuous_batching_matches_single_batch(tiny_engine):
    """Mixed-length traffic: every request's token stream must equal the
    tokens it gets when generated alone (greedy)."""
    serve = ServeConfig(max_batch=3, max_seq_len=64, top_k=1,
                        page_size=16, num_pages=10)
    engine, cfg = tiny_engine(serve)
    rng = np.random.default_rng(0)
    spec = [(5, 6), (9, 3), (3, 10), (7, 4), (12, 2)]
    reqs = [Request(id=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s),
                    max_new_tokens=n)
            for i, (s, n) in enumerate(spec)]
    events = list(engine.generate_stream(reqs))

    # every request ran to completion, tokens streamed in order
    assert all(r.state == FINISHED for r in reqs)
    assert len(events) == sum(n for _, n in spec)
    for r in reqs:
        mine = [e for e in events if e.request_id == r.id]
        assert [e.token for e in mine] == r.generated
        assert [e.index for e in mine] == list(range(r.max_new_tokens))
        assert [e.finished for e in mine] == \
            [False] * (r.max_new_tokens - 1) + [True]

    # queue drained through slot reuse: 5 requests through 3 slots
    assert len(engine.last_scheduler.finished) == 5
    # all pages reclaimed; the pool never grew beyond its configured size
    assert engine.last_cache.used_pages == 0
    assert engine.last_cache.peak_used_pages <= 9

    # per-request single-batch generation gives identical tokens
    for r in reqs:
        solo = Request(id=r.id, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)
        list(engine.generate_stream([solo]))
        assert solo.generated == r.generated, r.id


def test_stream_matches_dense_generate(tiny_engine):
    """The paged+scheduled path reproduces the dense static-batch
    engine's greedy tokens exactly."""
    import jax.numpy as jnp
    serve = ServeConfig(max_batch=2, max_seq_len=64, top_k=1,
                        page_size=16)
    engine, cfg = tiny_engine(serve)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6)
    dense = np.asarray(engine.generate(jnp.asarray(prompt[None]), 8))[0]
    req = Request(id=0, prompt=prompt, max_new_tokens=8)
    list(engine.generate_stream([req]))
    assert req.generated == dense.tolist()


def test_decode_interleaves_with_long_prefill(tiny_engine):
    """A long newcomer prompt must not stall running decode slots: with a
    per-step prefill token budget, the short sequence keeps producing
    tokens between the long prompt's chunks, and the long prompt's first
    token only arrives after several engine steps."""
    serve = ServeConfig(max_batch=2, max_seq_len=128, top_k=1,
                        page_size=8, prefill_chunk=8,
                        prefill_token_budget=8)
    engine, cfg = tiny_engine(serve)
    rng = np.random.default_rng(7)
    short = Request(id=0, prompt=rng.integers(0, cfg.vocab_size, size=4),
                    max_new_tokens=12)
    long = Request(id=1, prompt=rng.integers(0, cfg.vocab_size, size=48),
                   max_new_tokens=2)
    events = list(engine.generate_stream([short, long]))

    first_long = next(i for i, e in enumerate(events)
                      if e.request_id == 1)
    short_before = sum(1 for e in events[:first_long]
                       if e.request_id == 0)
    # the long prompt needs 48/8 = 6 chunk steps (minus the step its
    # admission shares with short's whole prefill); short decodes once
    # per step in the meantime
    assert short_before >= 4, (short_before, events)
    assert all(r.state == FINISHED for r in (short, long))

    # interleaving must not change what either sequence decodes
    for r in (short, long):
        solo = Request(id=r.id, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)
        list(engine.generate_stream([solo]))
        assert solo.generated == r.generated, r.id


def test_pool_too_small_raises(tiny_engine):
    serve = ServeConfig(max_batch=2, max_seq_len=64, top_k=1,
                        page_size=16, num_pages=3)
    engine, cfg = tiny_engine(serve)
    req = Request(id=0, prompt=np.arange(10), max_new_tokens=30)
    with pytest.raises(ValueError, match="pool"):
        list(engine.generate_stream([req]))


def test_swap_resume_admission_out_of_pages_propagates():
    """Regression: the swap-resume admission branch catches OutOfPages --
    which was never imported into the module, so an actually-dry pool
    raised NameError from the except clause itself.  Drive a swap-resume
    admission into a pool whose append runs dry and assert the real
    exception propagates with the slot cleanly released."""
    from repro.serving.paged_cache import OutOfPages

    cache = PagedKVCache(num_pages=16, page_size=4, max_slots=2,
                         max_pages_per_seq=8)
    sched = ContinuousBatchScheduler(cache)
    req = _req(0, 8, 8)
    sched.submit(req)
    # fake a swap preemption: KV stashed to host, request queued to resume
    sched.waiting.clear()
    req.state = "PREEMPTED"
    req.resume_kind = "swap"
    req.resume_len = 8
    sched.resuming.append(req)

    def dry_append(slot, n):
        raise OutOfPages("pool drained between headroom check and append")

    cache.append = dry_append
    with pytest.raises(OutOfPages):
        sched.admit()
    del cache.append                     # restore the real method
    # clean failure: no leaked slot, no leaked pages
    assert all(r is None for r in sched.slots)
    assert cache.used_pages == 0
    cache.check_invariants()
