"""repro-lint: framework semantics, the 8-rule catalogue (one
true-positive + one true-negative per rule), suppression honoring,
reporters, CLI exit codes, and the meta-test that the live tree is
clean.

Fixture modules are written under tmp_path at repo-shaped relative
paths (``repro/serving/...``) because several rules scope themselves by
path fragment; keeping them as string literals (not checked-in .py
files) means the CI sweep of ``src/ tests/`` never sees the deliberate
positives.
"""
from __future__ import annotations

import json
import textwrap

import pytest

from repro.analysis.lint import (ALL_RULES, RULE_INDEX, LintEngine,
                                 default_rules, lint_paths, render_json,
                                 render_text)
from repro.analysis.lint.cli import build_rules, main as lint_main
from repro.analysis.lint.framework import ModuleContext

SERVING = "repro/serving/mod.py"


def run_lint(tmp_path, sources, rules=None):
    """sources: {relpath: code} written under tmp_path, then swept."""
    if isinstance(sources, str):
        sources = {SERVING: sources}
    for rel, code in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(code))
    engine = LintEngine(default_rules() if rules is None else rules)
    return engine.run([str(tmp_path)])


def active_rules(result):
    return sorted({f.rule for f in result.active})


# ---------------------------------------------------------------------------
# REPRO001 unresolvable-except
# ---------------------------------------------------------------------------

def test_unresolvable_except_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        def admit(self):
            try:
                self.alloc()
            except OutOfPages:
                return None
    """)
    (f,) = [f for f in res.active if f.rule == "unresolvable-except"]
    assert "OutOfPages" in f.message and f.line == 5


def test_unresolvable_except_true_negative(tmp_path):
    res = run_lint(tmp_path, """
        from repro.serving.paging import OutOfPages
        import errors

        def admit(self):
            LocalError = ValueError
            try:
                self.alloc()
            except (OutOfPages, errors.Timeout, LocalError):
                return None
            except ValueError:
                return None
    """)
    assert "unresolvable-except" not in active_rules(res)


# ---------------------------------------------------------------------------
# REPRO002 raw-wall-clock
# ---------------------------------------------------------------------------

def test_raw_wall_clock_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        import time
        from time import perf_counter

        def step(self):
            t0 = time.perf_counter()
            t1 = perf_counter()
            return t1 - t0
    """)
    hits = [f for f in res.active if f.rule == "raw-wall-clock"]
    assert [f.line for f in hits] == [6, 7]


def test_raw_wall_clock_true_negative(tmp_path):
    # binding the function (no call) and reading through the injectable
    # attribute are both the sanctioned pattern
    res = run_lint(tmp_path, """
        import time

        class Core:
            def __init__(self, clock=None):
                self._clock = clock or time.monotonic

            def step(self):
                return self._clock()
    """)
    assert "raw-wall-clock" not in active_rules(res)


def test_raw_wall_clock_scoped_to_engine_paths(tmp_path):
    # the same raw read outside serving/launch/training is not this
    # rule's business
    res = run_lint(tmp_path, {"repro/kernels/mod.py": """
        import time

        def bench():
            return time.perf_counter()
    """})
    assert "raw-wall-clock" not in active_rules(res)


# ---------------------------------------------------------------------------
# REPRO003 mutable-default
# ---------------------------------------------------------------------------

def test_mutable_default_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        from dataclasses import dataclass

        def collect(x, acc=[], *, index={}):
            acc.append(x)

        @dataclass
        class Params:
            stop_strings: list = []
    """)
    hits = [f for f in res.active if f.rule == "mutable-default"]
    assert len(hits) == 3
    assert any("'acc'" in f.message for f in hits)
    assert any("default_factory" in f.message for f in hits)


def test_mutable_default_true_negative(tmp_path):
    res = run_lint(tmp_path, """
        from dataclasses import dataclass, field

        def collect(x, acc=None, *, index=None, k=3, name="q"):
            acc = [] if acc is None else acc

        @dataclass
        class Params:
            stop_strings: list = field(default_factory=list)

        class NotADataclass:
            registry = {}     # class attr on a plain class: fine
    """)
    assert "mutable-default" not in active_rules(res)


# ---------------------------------------------------------------------------
# REPRO004 trace-impurity
# ---------------------------------------------------------------------------

def test_trace_impurity_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        import time
        import jax

        def decode(params, tok, core):
            core.count += 1
            print("decoding", tok)
            t = time.perf_counter()
            return tok

        run = jax.jit(decode)
    """)
    msgs = [f.message for f in res.active if f.rule == "trace-impurity"]
    assert len(msgs) == 3
    assert any("mutates attribute" in m for m in msgs)
    assert any("print()" in m for m in msgs)
    assert any("host clock" in m for m in msgs)


def test_trace_impurity_comprehension_seeding(tmp_path):
    # the EngineCore idiom: tuple(jit(f) for f in (a, b)) must seed
    # every name in the iterated tuple
    res = run_lint(tmp_path, """
        import jax

        def pre(params, x, core):
            core.traces += 1
            return x

        def dec(params, x, core):
            return x

        fns = tuple(jax.jit(f) for f in (pre, dec))
    """)
    hits = [f for f in res.active if f.rule == "trace-impurity"]
    assert len(hits) == 1 and "core.traces" in hits[0].message


def test_trace_impurity_tracer_truthiness(tmp_path):
    res = run_lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def guard(logits):
            if jnp.any(jnp.isnan(logits)):
                return logits * 0
            return logits
    """)
    hits = [f for f in res.active if f.rule == "trace-impurity"]
    assert len(hits) == 1 and "truthiness" in hits[0].message


def test_trace_impurity_true_negative(tmp_path):
    # pure traced fn; host-side print/clock outside the traced region
    res = run_lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def decode(params, tok, causal=True):
            if causal:                      # static python flag: fine
                tok = tok + 1
            return jnp.maximum(tok, 0)

        def host_loop(clock):
            print("stepping")
            return clock()
    """)
    assert "trace-impurity" not in active_rules(res)


# ---------------------------------------------------------------------------
# REPRO005 retrace-hazard
# ---------------------------------------------------------------------------

def test_retrace_hazard_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        run = jax.jit(lambda p, t: t)

        def prefill(self, req, start):
            toks = req.prefill_tokens[start:]
            return run(self.params, jnp.asarray(toks[None]))
    """)
    hits = [f for f in res.active if f.rule == "retrace-hazard"]
    assert len(hits) == 1
    assert "prefill_tokens" in hits[0].message and hits[0].line == 9


def test_retrace_hazard_true_negative(tmp_path):
    # config-bounded chunk shapes never taint the jitted call
    res = run_lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        run = jax.jit(lambda p, t: t)

        def prefill(self, req):
            chunk = jnp.zeros((self.serve.prefill_chunk,), jnp.int32)
            return run(self.params, chunk)
    """)
    assert "retrace-hazard" not in active_rules(res)


# ---------------------------------------------------------------------------
# REPRO006 metric-name-hygiene
# ---------------------------------------------------------------------------

def test_metric_name_hygiene_true_positive(tmp_path):
    res = run_lint(tmp_path, {"repro/serving/m.py": """
        def setup(m):
            m.counter("engine_steps", help="missing total suffix")
            m.counter("requests_total", help="unknown prefix")
            m.histogram("engine_step_ms", (), help="bad unit")
    """})
    hits = [f for f in res.active if f.rule == "metric-name-hygiene"]
    assert len(hits) == 3
    assert any("_total" in f.message for f in hits)
    assert any("prefix" in f.message for f in hits)
    assert any("unit suffix" in f.message for f in hits)


def test_metric_name_hygiene_true_negative(tmp_path):
    res = run_lint(tmp_path, {"repro/serving/m.py": """
        def setup(m, k, phase):
            m.counter("engine_steps_total", help="ok")
            m.histogram("engine_step_seconds", (), help="ok")
            m.gauge("kv_pages_used", help="ok")
            m.inc(f"pressure_{k}_total")
            m.observe(f"engine_phase_{phase}_seconds", 0.1)
            # non-registry .set()/.inc() with non-str first arg: ignored
            arr.at[0].set(1.0)
            counter_obj.inc(3)
    """})
    assert "metric-name-hygiene" not in active_rules(res)


def test_metric_duplicate_creation_site_across_modules(tmp_path):
    res = run_lint(tmp_path, {
        "repro/serving/a.py": """
            def setup(m):
                m.counter("engine_dup_total", help="owner")
        """,
        "repro/serving/b.py": """
            def setup(m):
                m.counter("engine_dup_total", help="squatter")
        """,
    })
    hits = [f for f in res.active if f.rule == "metric-name-hygiene"]
    assert len(hits) == 1
    assert "more than one site" in hits[0].message
    assert hits[0].path.endswith("b.py")      # first site is the owner


# ---------------------------------------------------------------------------
# REPRO007 silent-drop
# ---------------------------------------------------------------------------

def test_silent_drop_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        from collections import deque

        class EventBus:
            def __init__(self):
                self.orphans = deque(maxlen=1024)
    """)
    hits = [f for f in res.active if f.rule == "silent-drop"]
    assert len(hits) == 1 and hits[0].line == 6


def test_silent_drop_true_negative(tmp_path):
    res = run_lint(tmp_path, """
        from collections import deque

        class CountingBus:
            def __init__(self):
                self.orphans = deque(maxlen=1024)
                self.dropped = 0

        class Unbounded:
            def __init__(self):
                self.log = deque()
                self.log2 = deque(maxlen=None)
    """)
    assert "silent-drop" not in active_rules(res)


# ---------------------------------------------------------------------------
# REPRO008 swallowed-exception
# ---------------------------------------------------------------------------

def test_swallowed_exception_true_positive(tmp_path):
    res = run_lint(tmp_path, """
        def step(self):
            try:
                self.launch()
            except:
                pass

        def drain(self):
            try:
                self.flush()
            except Exception:
                self.ok = False
    """)
    hits = [f for f in res.active if f.rule == "swallowed-exception"]
    assert len(hits) == 2
    assert "bare except" in hits[0].message


def test_swallowed_exception_true_negative(tmp_path):
    res = run_lint(tmp_path, """
        def step(self):
            try:
                self.launch()
            except Exception as e:
                raise EngineError(str(e))

        def drain(self):
            try:
                self.flush()
            except ValueError:
                pass                      # specific: allowed

        def log_it(self):
            try:
                self.flush()
            except Exception as e:
                self.log.warning("flush failed: %s", e)
    """)
    assert "swallowed-exception" not in active_rules(res)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_inline_suppression_honored(tmp_path):
    res = run_lint(tmp_path, """
        import time

        def step(self):
            return time.perf_counter()  # repro-lint: disable=raw-wall-clock (why)
    """)
    assert res.active == [] and len(res.suppressed) == 1
    assert res.suppressed[0].rule == "raw-wall-clock"


def test_standalone_comment_suppresses_next_line(tmp_path):
    res = run_lint(tmp_path, """
        import time

        def step(self):
            # repro-lint: disable=raw-wall-clock
            return time.perf_counter()
    """)
    assert res.active == [] and len(res.suppressed) == 1


def test_file_pragma_and_disable_all(tmp_path):
    res = run_lint(tmp_path, {SERVING: """
        # repro-lint: disable-file=raw-wall-clock
        import time

        def a(self):
            return time.time()

        def b(self, x=[]):       # repro-lint: disable=all
            return time.monotonic()

        def c(self, y={}):
            pass
    """})
    # the file pragma covers every clock read; disable=all covers b's
    # mutable default; c's default is the one live finding
    assert [f.rule for f in res.active] == ["mutable-default"]
    assert res.active[0].line == 11
    assert {f.rule for f in res.suppressed} >= {"raw-wall-clock",
                                                "mutable-default"}


def test_suppression_does_not_leak_to_other_rules(tmp_path):
    res = run_lint(tmp_path, """
        import time

        def step(self):
            return time.perf_counter()  # repro-lint: disable=silent-drop
    """)
    assert [f.rule for f in res.active] == ["raw-wall-clock"]


# ---------------------------------------------------------------------------
# reporters + CLI
# ---------------------------------------------------------------------------

def test_json_reporter_schema(tmp_path):
    res = run_lint(tmp_path, """
        import time

        def step(self):
            return time.time()
    """)
    payload = json.loads(render_json(res))
    assert payload["tool"] == "repro-lint" and payload["version"] == 1
    assert payload["files_checked"] == 1
    assert payload["summary"]["errors"] == 1
    (f,) = payload["findings"]
    assert set(f) == {"rule", "code", "severity", "path", "line", "col",
                      "message", "suppressed"}
    assert f["rule"] == "raw-wall-clock" and f["code"] == "REPRO002"
    assert f["line"] == 5 and f["suppressed"] is False


def test_text_reporter_locations(tmp_path):
    res = run_lint(tmp_path, """
        import time

        def step(self):
            return time.time()
    """)
    out = render_text(res)
    assert "mod.py:5:" in out and "[REPRO002 raw-wall-clock]" in out
    assert "1 findings" in out


def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "repro" / "serving" / "ok.py"
    clean.parent.mkdir(parents=True)
    clean.write_text("x = 1\n")
    assert lint_main([str(tmp_path)]) == 0
    dirty = tmp_path / "repro" / "serving" / "bad.py"
    dirty.write_text("import time\n\n\ndef f():\n"
                     "    return time.time()\n")
    assert lint_main([str(tmp_path), "--format=json"]) == 1
    out = capsys.readouterr().out
    payload = json.loads(out[out.index("{"):])
    assert payload["summary"]["errors"] == 1
    assert lint_main([]) == 2                      # no paths
    assert lint_main([str(tmp_path), "--select", "nope"]) == 2


def test_cli_select_and_ignore(tmp_path):
    bad = tmp_path / "repro" / "serving" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\n\ndef f(x=[]):\n"
                   "    return time.time()\n")
    # only mutable-default selected: the clock read is not reported
    rules = build_rules(select=["mutable-default"])
    res = LintEngine(rules).run([str(tmp_path)])
    assert active_rules(res) == ["mutable-default"]
    rules = build_rules(ignore=["mutable-default"])
    res = LintEngine(rules).run([str(tmp_path)])
    assert active_rules(res) == ["raw-wall-clock"]


def test_cli_severity_override(tmp_path):
    bad = tmp_path / "repro" / "serving" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\n\ndef f():\n"
                   "    return time.time()\n")
    rules = build_rules(severity=["raw-wall-clock=warning"])
    res = LintEngine(rules).run([str(tmp_path)])
    assert len(res.active) == 1 and res.errors == []
    # warnings don't fail the CLI
    assert lint_main([str(tmp_path), "--severity",
                      "raw-wall-clock=warning"]) == 0


def test_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    res = LintEngine(default_rules()).run([str(tmp_path)])
    (f,) = res.active
    assert f.code == "REPRO000" and f.rule == "syntax-error"


def test_rule_catalogue_complete():
    assert len(ALL_RULES) == 8
    assert len({r.code for r in ALL_RULES}) == 8
    assert set(RULE_INDEX) == {
        "unresolvable-except", "raw-wall-clock", "mutable-default",
        "trace-impurity", "retrace-hazard", "metric-name-hygiene",
        "silent-drop", "swallowed-exception"}
    for r in ALL_RULES:
        assert r.description and r.code.startswith("REPRO")


# ---------------------------------------------------------------------------
# the meta-tests: the live tree is clean, and known bug classes are
# caught when reintroduced
# ---------------------------------------------------------------------------

def _repo_root():
    import os
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(here)


def test_live_tree_lints_clean():
    import os
    root = _repo_root()
    res = lint_paths(os.path.join(root, "src"),
                     os.path.join(root, "tests"))
    assert res.files_checked > 80
    assert res.active == [], "\n" + render_text(res)
    # the sweep is real: the intentional sites are suppressed, not absent
    assert len(res.suppressed) >= 15


@pytest.mark.parametrize("snippet,rule", [
    # PR 6's bug: except on a name the module never imports
    ("""
     def admit(self):
         try:
             self.alloc()
         except OutOfPages:
             pass
     """, "unresolvable-except"),
    # PR 8's bug: stray perf_counter inside engine code
    ("""
     import time

     def step(self):
         t0 = time.perf_counter()
         return t0
     """, "raw-wall-clock"),
])
def test_reintroduced_bug_classes_fail_the_gate(tmp_path, snippet, rule):
    res = run_lint(tmp_path, snippet)
    hits = [f for f in res.active if f.rule == rule]
    assert hits, f"{rule} did not fire on its historical bug class"
    assert all(f.path.endswith("mod.py") and f.line > 1 for f in hits)


def test_suppression_regex_tolerates_justifications(tmp_path):
    # the recommended style: a parenthetical why after the rule token
    src = ("import time\n\n\ndef f():\n    return time.time()  "
           "# repro-lint: disable=raw-wall-clock (heartbeat)\n")
    p = tmp_path / "repro" / "serving" / "j.py"
    p.parent.mkdir(parents=True)
    p.write_text(src)
    res = LintEngine(default_rules()).run([str(tmp_path)])
    assert res.active == [] and len(res.suppressed) == 1
