"""FastAttention kernel vs pure-jnp oracle: shape/dtype/feature sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fastattn.kernel import fastattn_fwd
from repro.kernels.fastattn.ops import fastattn
from repro.kernels.fastattn.ref import flash_reference, standard_attention

CASES = [
    # (B, Hq, Hkv, Sq, Skv, D, causal, window, softcap, bq, bkv1, bkv2)
    (2, 4, 2, 384, 384, 64, True, None, None, 128, 256, 128),
    (1, 2, 2, 512, 512, 64, True, 100, None, 128, 256, 128),
    (1, 2, 1, 256, 256, 64, True, None, 30.0, 128, 256, 128),
    (1, 3, 1, 300, 200, 64, True, None, None, 128, 256, 128),
    (1, 2, 2, 256, 384, 64, False, None, None, 128, 256, 128),
    (1, 2, 1, 512, 512, 32, True, 200, 50.0, 128, 512, 128),
    (1, 1, 1, 64, 64, 16, True, None, None, 128, 256, 128),
    (2, 8, 2, 256, 256, 128, True, None, None, 256, 256, 256),
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_standard_attention(case):
    b, hq, hkv, sq, skv, d, causal, window, softcap, bq, bkv1, bkv2 = case
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    ref = standard_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    out = fastattn_fwd(q, k, v, causal=causal, window=window,
                       softcap=softcap, block_q=bq, block_kv1=bkv1,
                       block_kv2=bkv2, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_kernel_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype)
    ref = standard_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32))
    out = fastattn_fwd(q, k, v, block_q=128, block_kv1=256, block_kv2=128,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=0.05, atol=tol * 10)


def test_q_offset_chunked_prefill_equivalence():
    """Chunked prefill with q_offset must equal one-shot prefill."""
    rng = np.random.default_rng(3)
    b, h, s, d = 1, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    full = fastattn_fwd(q, k, v, block_q=128, block_kv1=128,
                        block_kv2=128, interpret=True)
    halves = []
    for off in (0, 256):
        halves.append(fastattn_fwd(
            q[:, :, off:off + 256], k[:, :, :off + 256],
            v[:, :, :off + 256], q_offset=off, block_q=128,
            block_kv1=128, block_kv2=128, interpret=True))
    np.testing.assert_allclose(np.asarray(jnp.concatenate(halves, axis=2)),
                               np.asarray(full), rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("impl", ["interpret", "reference"])
def test_kv_valid_masks_padded_tail(impl):
    """fastattn(kv_valid=n) on zero-padded K/V == fastattn on K/V[:n]
    (a gathered paged view whose last page is partially filled)."""
    rng = np.random.default_rng(9)
    b, hq, hkv, d, n, s_pad = 1, 4, 2, 32, 147, 192
    q = jnp.asarray(rng.normal(size=(b, hq, n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s_pad, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s_pad, d)), jnp.float32)
    exact = fastattn(q, k[:, :, :n], v[:, :, :n], True, None, None, None,
                     0, 64, 64, 64, impl)
    cut = fastattn(q, k, v, True, None, None, None, 0, 64, 64, 64, impl, n)
    np.testing.assert_allclose(np.asarray(cut), np.asarray(exact),
                               rtol=1e-4, atol=2e-5)


def _paged_fixture(seed=0, lens=(19, 33), hkv=2, hq=4, d=16, ps=8,
                   pool=16, n_kv=6):
    """Two sequences scattered across a scrambled page pool."""
    rng = np.random.default_rng(seed)
    table = np.zeros((len(lens), n_kv), np.int32)
    free = list(rng.permutation(np.arange(1, pool)))
    for b, n in enumerate(lens):
        for i in range(-(-n // ps)):
            table[b, i] = free.pop()
    k_pages = jnp.asarray(rng.normal(size=(hkv, pool, ps, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(hkv, pool, ps, d)), jnp.float32)
    return rng, jnp.asarray(table), k_pages, v_pages, hq


@pytest.mark.parametrize("kw", [dict(), dict(window=5), dict(softcap=10.0),
                                dict(window=7, softcap=25.0)])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_paged_prefill_matches_dense_oracle(kw, use_kernel):
    """Chunked-prefill attention through a scrambled page table (kernel in
    interpret mode + gather reference) == dense standard attention with
    the chunk's global q_offset, for ragged per-sequence offsets."""
    from repro.kernels.fastattn.ops import fastattn_paged_prefill
    from repro.kernels.flash_decode.ref import (paged_gather,
                                                paged_prefill_reference)
    lens = (19, 33)
    c = 7                                    # chunk: the last 7 tokens
    rng, table, k_pages, v_pages, hq = _paged_fixture(lens=lens)
    d = k_pages.shape[-1]
    q = jnp.asarray(rng.normal(size=(len(lens), hq, c, d)), jnp.float32)
    pos_start = jnp.asarray([n - c for n in lens], jnp.int32)
    kv_len = jnp.asarray(lens, jnp.int32)
    if use_kernel:
        out = fastattn_paged_prefill(q, k_pages, v_pages, table, pos_start,
                                     kv_len, block_q=8, interpret=True,
                                     **kw)
    else:
        out = paged_prefill_reference(q, k_pages, v_pages, table, pos_start,
                                      kv_len, **kw)
    dense_k = paged_gather(k_pages, table)
    dense_v = paged_gather(v_pages, table)
    for b, n in enumerate(lens):
        ref = standard_attention(
            q[b:b + 1], dense_k[b:b + 1, :, :n], dense_v[b:b + 1, :, :n],
            causal=True, q_offset=n - c, **kw)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(ref), rtol=1e-4, atol=2e-5)


def test_paged_prefill_padded_chunk_window_stays_in_table():
    """A fixed-size chunk whose padding rows run past the page-table
    capacity must not push the windowed KV index map out of the table
    (regression: `first` was unclamped for fully-padded q blocks)."""
    from repro.kernels.fastattn.ops import fastattn_paged_prefill
    from repro.kernels.flash_decode.ref import paged_gather
    rng = np.random.default_rng(21)
    hkv, hq, d, ps, n_kv, pool = 1, 2, 16, 8, 4, 6
    table = jnp.asarray(np.arange(1, n_kv + 1, dtype=np.int32)[None])
    k_pages = jnp.asarray(rng.normal(size=(hkv, pool, ps, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(hkv, pool, ps, d)), jnp.float32)
    # chunk starts at 28 with 4 valid rows: kv_len == table capacity (32),
    # but the 12-row chunk pads to 2 q blocks of 8 -- the second block's
    # window start lands past the last table entry
    pos_start = jnp.asarray([28], jnp.int32)
    n_valid, sq = 4, 12
    kv_len = pos_start + n_valid
    q = jnp.asarray(rng.normal(size=(1, hq, sq, d)), jnp.float32)
    out = fastattn_paged_prefill(q, k_pages, v_pages, table, pos_start,
                                 kv_len, window=4, block_q=8,
                                 interpret=True)
    dense_k = paged_gather(k_pages, table)
    dense_v = paged_gather(v_pages, table)
    ref = standard_attention(q[:, :, :n_valid], dense_k, dense_v,
                             causal=True, window=4, q_offset=28)
    np.testing.assert_allclose(np.asarray(out[:, :, :n_valid]),
                               np.asarray(ref), rtol=1e-4, atol=2e-5)


def test_flash_reference_dynamic_q_offset_matches_static():
    """Traced per-batch q offsets (the chunked-prefill path) must equal
    the static-int q_offset path."""
    from repro.kernels.fastattn.ref import flash_reference_with_lse
    rng = np.random.default_rng(13)
    b, h, c, s, d = 2, 2, 5, 40, 16
    q = jnp.asarray(rng.normal(size=(b, h, c, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    off = 23
    stat, _ = flash_reference_with_lse(q, k, v, q_offset=off, block_kv=16)
    dyn, _ = jax.jit(lambda q, k, v, o: flash_reference_with_lse(
        q, k, v, q_offset=o, block_kv=16))(
            q, k, v, jnp.full((b,), off, jnp.int32))
    np.testing.assert_allclose(np.asarray(dyn), np.asarray(stat),
                               rtol=1e-5, atol=1e-6)


def test_flash_reference_matches_standard():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 4, 200, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 300, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 300, 32)), jnp.float32)
    for kw in [dict(causal=True), dict(causal=False),
               dict(causal=True, window=64),
               dict(causal=True, softcap=20.0)]:
        ref = standard_attention(q, k, v, **kw)
        out = flash_reference(q, k, v, block_kv=128, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=2e-5)


def test_custom_vjp_backward_close_to_standard():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(fastattn(q, k, v, True, None, None, None, 0,
                                128, 128, 128, "interpret") ** 2)

    def f_ref(q, k, v):
        return jnp.sum(standard_attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
