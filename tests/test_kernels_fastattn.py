"""FastAttention kernel vs pure-jnp oracle: shape/dtype/feature sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fastattn.kernel import fastattn_fwd
from repro.kernels.fastattn.ops import fastattn
from repro.kernels.fastattn.ref import flash_reference, standard_attention

CASES = [
    # (B, Hq, Hkv, Sq, Skv, D, causal, window, softcap, bq, bkv1, bkv2)
    (2, 4, 2, 384, 384, 64, True, None, None, 128, 256, 128),
    (1, 2, 2, 512, 512, 64, True, 100, None, 128, 256, 128),
    (1, 2, 1, 256, 256, 64, True, None, 30.0, 128, 256, 128),
    (1, 3, 1, 300, 200, 64, True, None, None, 128, 256, 128),
    (1, 2, 2, 256, 384, 64, False, None, None, 128, 256, 128),
    (1, 2, 1, 512, 512, 32, True, 200, 50.0, 128, 512, 128),
    (1, 1, 1, 64, 64, 16, True, None, None, 128, 256, 128),
    (2, 8, 2, 256, 256, 128, True, None, None, 256, 256, 256),
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_standard_attention(case):
    b, hq, hkv, sq, skv, d, causal, window, softcap, bq, bkv1, bkv2 = case
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    ref = standard_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    out = fastattn_fwd(q, k, v, causal=causal, window=window,
                       softcap=softcap, block_q=bq, block_kv1=bkv1,
                       block_kv2=bkv2, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_kernel_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype)
    ref = standard_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32))
    out = fastattn_fwd(q, k, v, block_q=128, block_kv1=256, block_kv2=128,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=0.05, atol=tol * 10)


def test_q_offset_chunked_prefill_equivalence():
    """Chunked prefill with q_offset must equal one-shot prefill."""
    rng = np.random.default_rng(3)
    b, h, s, d = 1, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    full = fastattn_fwd(q, k, v, block_q=128, block_kv1=128,
                        block_kv2=128, interpret=True)
    halves = []
    for off in (0, 256):
        halves.append(fastattn_fwd(
            q[:, :, off:off + 256], k[:, :, :off + 256],
            v[:, :, :off + 256], q_offset=off, block_q=128,
            block_kv1=128, block_kv2=128, interpret=True))
    np.testing.assert_allclose(np.asarray(jnp.concatenate(halves, axis=2)),
                               np.asarray(full), rtol=1e-4, atol=2e-5)


def test_flash_reference_matches_standard():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 4, 200, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 300, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 300, 32)), jnp.float32)
    for kw in [dict(causal=True), dict(causal=False),
               dict(causal=True, window=64),
               dict(causal=True, softcap=20.0)]:
        ref = standard_attention(q, k, v, **kw)
        out = flash_reference(q, k, v, block_kv=128, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=2e-5)


def test_custom_vjp_backward_close_to_standard():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(fastattn(q, k, v, True, None, None, None, 0,
                                128, 128, 128, "interpret") ** 2)

    def f_ref(q, k, v):
        return jnp.sum(standard_attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
