"""Optional-hypothesis shim.

Importing ``given``/``settings``/``st`` from here (instead of from
hypothesis directly) lets a module's property tests skip cleanly when
hypothesis is absent while the plain unit tests in the same module keep
running -- a module-level ``pytest.importorskip`` would silently drop
those too.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _skipping_decorator(*_a, **_k):
        def wrap(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return wrap

    given = settings = _skipping_decorator

    class _DummyStrategies:
        """Any strategy lookup returns an inert callable so module-level
        ``@given(st.floats(...))`` expressions still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _DummyStrategies()
    hnp = _DummyStrategies()
