"""Per-architecture smoke tests: reduced config, one forward + one train
step + decode-vs-prefill consistency on CPU; asserts shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.config import (ParallelConfig, TrainConfig, get_model_config,
                          reduce_for_smoke)
from repro.models import build_model
from repro.training.train_step import init_train_state, make_train_step

ARCHS = list(C.ASSIGNED_ARCHS)


def _batch_for(cfg, b, s, key):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                                jnp.float32)
        return {"enc_embeds": enc, "tokens": toks, "labels": labels}
    if cfg.modality == "vision_stub":
        emb = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
        return {"inputs_embeds": emb, "positions": pos, "labels": labels}
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = reduce_for_smoke(get_model_config(arch))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(1))
    if cfg.is_encoder_decoder:
        logits = model.apply(params, batch["enc_embeds"], batch["tokens"])
    elif cfg.modality == "vision_stub":
        logits = model.apply(params, inputs_embeds=batch["inputs_embeds"],
                             positions=batch["positions"])
    else:
        logits = model.apply(params, batch["tokens"])
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = reduce_for_smoke(get_model_config(arch))
    parallel = ParallelConfig(remat="selective")
    model = build_model(cfg, parallel)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=2)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, cfg, parallel, tcfg))
    batch = _batch_for(cfg, 2, 32, jax.random.PRNGKey(1))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma2-2b", "xlstm-125m",
                                  "hymba-1.5b", "whisper-small",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_prefill(arch):
    cfg = reduce_for_smoke(get_model_config(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(jax.random.PRNGKey(3),
                                (b, cfg.encoder_seq, cfg.d_model))
        full = model.apply(params, enc, toks)
        enc_out = model.encode(params, enc)
        cache = model.init_cache(b, s + 4, enc_out=enc_out, params=params)
    else:
        full = model.apply(params, toks)
        cache = model.init_cache(b, s + 4)
    for t in range(s):
        lg, cache = model.decode_step(params, toks[:, t], cache, t)
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_grad_accumulation_equivalence():
    """microbatches=2 must match microbatches=1 on the same global batch."""
    cfg = reduce_for_smoke(get_model_config("stablelm-3b"))
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=2)
    batch = _batch_for(cfg, 4, 16, jax.random.PRNGKey(5))
    losses = []
    for mb in (1, 2):
        parallel = ParallelConfig(remat="none", microbatches=mb)
        model = build_model(cfg, parallel)
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, cfg, parallel, tcfg))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert abs(losses[0] - losses[1]) < 1e-4
