"""Tensor-parallel paged serving: greedy output on a 2/4-way forced-CPU
mesh must be bit-identical to the single-device paged engine.

Subprocess isolation (like test_distributed.py): children run with
XLA_FLAGS forcing fake host devices so the main pytest process keeps its
single-device view.  tp=4 on the 2-KV-head smoke configs exercises the
full factoring -- 2 kv-head groups x 2 page-row sub-shards -- so the
cross-shard LSE merge is load-bearing, not degenerate.
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 4) -> dict:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


CHILD_PRELUDE = """
import json
import jax
import numpy as np
from repro.config import ServeConfig, get_model_config, reduce_for_smoke
from repro.models import build_model
from repro.config import ParallelConfig
from repro.serving.core import EngineCore
from repro.serving.scheduler import SamplingParams

cfg = reduce_for_smoke(get_model_config('gemma2-2b'))
model = build_model(cfg, ParallelConfig(remat='none'))
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(7)


def run(prompts, max_new, **serve_kw):
    serve_kw.setdefault('max_batch', 3)
    serve_kw.setdefault('max_seq_len', 96)
    serve_kw.setdefault('page_size', 16)
    serve_kw.setdefault('prefill_chunk', 16)
    core = EngineCore(model=model, params=params, cfg=cfg,
                      serve=ServeConfig(**serve_kw))
    for p in prompts:
        core.add_request(p, SamplingParams(max_new_tokens=max_new))
    toks = {}
    while core.has_work:
        for ev in core.step():
            toks.setdefault(ev.request_id, []).append(ev.token)
    return toks, core
"""


def test_tp_greedy_bit_identical_2_and_4_way():
    """tp=2 (pure head parallelism) and tp=4 (2 head groups x 2 page-row
    sub-shards, LSE merge active) against the tp=1 engine, under both
    collective modes."""
    r = run_child(CHILD_PRELUDE + """
prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
           for n in (5, 23, 40)]
base, _ = run(prompts, 8, num_pages=24, tp=1)
report = {'devices': jax.device_count(), 'match': {}}
for tp in (2, 4):
    for coll in ('tiled', 'single'):
        got, core = run(prompts, 8, num_pages=24, tp=tp,
                        tp_collectives=coll)
        report['match'][f'tp{tp}-{coll}'] = got == base
        report[f'tp{tp}-{coll}-plan'] = core.stats()['tp']
print(json.dumps(report))
""")
    assert r["devices"] == 4
    for key, ok in r["match"].items():
        assert ok, key
    # the 4-way factoring on 2 KV heads must split pages, not just heads
    assert r["tp4-tiled-plan"] == {"tp": 4, "g": 2, "s": 2,
                                   "collectives": "tiled"}
    assert r["tp2-tiled-plan"]["s"] == 1


def test_tp_bit_identical_under_preemption_and_prefix_sharing():
    """The hard serving paths stay bit-identical under TP: an
    oversubscribed pool forcing swap/recompute preemption, and a shared
    radix prefix with copy-on-write pages."""
    r = run_child(CHILD_PRELUDE + """
report = {}

# --- preemption: pool at ~60% of worst-case concurrent demand ---------
spec = [(8, 56), (5, 43), (20, 44), (4, 44), (30, 34), (6, 58)]
prompts = [rng.integers(0, cfg.vocab_size, size=s).tolist()
           for s, _ in spec]


def run_spec(**kw):
    core = EngineCore(model=model, params=params, cfg=cfg,
                      serve=ServeConfig(max_batch=4, max_seq_len=64,
                                        page_size=16, prefill_chunk=16,
                                        num_pages=14, **kw))
    for p, (_, n) in zip(prompts, spec):
        core.add_request(p, SamplingParams(max_new_tokens=n))
    toks = {}
    while core.has_work:
        for ev in core.step():
            toks.setdefault(ev.request_id, []).append(ev.token)
    return toks, core


base, core1 = run_spec()
assert core1.stats()['pressure']['preemptions'] > 0, \\
    core1.stats()['pressure']
got, core4 = run_spec(tp=4)
report['preempt_match'] = got == base
report['preemptions_tp4'] = core4.stats()['pressure']['preemptions']

# --- prefix sharing: common 24-token prefix, COW on divergence --------
# submit sequentially on one persistent core: the first request's
# retirement publishes its prefix blocks, the followers share them
shared = rng.integers(0, cfg.vocab_size, size=24).tolist()
tails = [rng.integers(0, cfg.vocab_size, size=6).tolist()
         for _ in range(3)]


def run_prefix(**kw):
    core = EngineCore(model=model, params=params, cfg=cfg,
                      serve=ServeConfig(max_batch=3, max_seq_len=96,
                                        page_size=16, prefill_chunk=16,
                                        num_pages=24, prefix_cache=True,
                                        **kw))
    toks = {}

    def drain():
        while core.has_work:
            for ev in core.step():
                toks.setdefault(ev.request_id, []).append(ev.token)

    core.add_request(shared + tails[0],
                     SamplingParams(max_new_tokens=8), request_id=0)
    drain()
    for i, tail in enumerate(tails[1:], start=1):
        core.add_request(shared + tail,
                         SamplingParams(max_new_tokens=8), request_id=i)
    drain()
    return toks, core


base, c1 = run_prefix()
assert c1.stats()['prefix']['hits'] > 0, c1.stats()['prefix']
got, c4 = run_prefix(tp=4)
report['prefix_match'] = got == base
report['prefix_hits_tp4'] = c4.stats()['prefix']['hits']
print(json.dumps(report))
""")
    assert r["preempt_match"], r
    assert r["preemptions_tp4"] > 0
    assert r["prefix_match"], r
    assert r["prefix_hits_tp4"] > 0


def test_tp_plan_validation():
    """plan_tp refuses impossible factorings instead of mis-sharding,
    and the engine refuses a tp larger than the device count."""
    r = run_child(CHILD_PRELUDE + """
from repro.sharding.tp import plan_tp
report = {}
plan = plan_tp(cfg, 4, 16)
report['g'], report['s'] = plan.g, plan.s
try:
    plan_tp(cfg, 4, 3)          # page_size 3 cannot split into s=2 rows
    report['page_guard'] = 'missed'
except ValueError:
    report['page_guard'] = 'raised'
try:
    EngineCore(model=model, params=params, cfg=cfg,
               serve=ServeConfig(tp=8, page_size=16))
    report['device_guard'] = 'missed'
except ValueError:
    report['device_guard'] = 'raised'
print(json.dumps(report))
""")
    assert (r["g"], r["s"]) == (2, 2)
    assert r["page_guard"] == "raised"
    assert r["device_guard"] == "raised"
