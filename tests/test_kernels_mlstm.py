"""mLSTM chunkwise kernel vs recurrent/parallel oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm.kernel import mlstm_chunkwise_fwd
from repro.kernels.mlstm.ref import (mlstm_chunkwise, mlstm_parallel,
                                     mlstm_recurrent)


def _inputs(b, h, s, dk, dv, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, h, s, dv)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, h, s)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, h, s)) + 2.0, jnp.float32))


def test_three_forms_agree():
    q, k, v, ig, fg = _inputs(2, 3, 256, 32, 48)
    hr, _ = mlstm_recurrent(q, k, v, ig, fg)
    hp = mlstm_parallel(q, k, v, ig, fg)
    hc = mlstm_chunkwise(q, k, v, ig, fg, chunk=64)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape", [
    (2, 2, 384, 32, 48, 128), (1, 4, 256, 64, 64, 128),
    (1, 1, 300, 16, 16, 128), (2, 2, 128, 32, 32, 128),
])
def test_kernel_vs_recurrent(shape):
    b, h, s, dk, dv, chunk = shape
    q, k, v, ig, fg = _inputs(b, h, s, dk, dv, seed=4)
    hr, st_r = mlstm_recurrent(q, k, v, ig, fg)
    hk, st_k = mlstm_chunkwise_fwd(q, k, v, ig, fg, chunk=chunk,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_k[0]), np.asarray(st_r[0]),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_k[1]), np.asarray(st_r[1]),
                               rtol=1e-3, atol=1e-3)


def test_state_handoff_streaming():
    """Chunkwise with carried state == one long recurrent pass."""
    q, k, v, ig, fg = _inputs(1, 2, 256, 16, 16, seed=9)
    hr, _ = mlstm_recurrent(q, k, v, ig, fg)
    h1, st = mlstm_chunkwise(q[:, :, :128], k[:, :, :128], v[:, :, :128],
                             ig[:, :, :128], fg[:, :, :128], chunk=64,
                             return_state=True)
    h2 = mlstm_chunkwise(q[:, :, 128:], k[:, :, 128:], v[:, :, 128:],
                         ig[:, :, 128:], fg[:, :, 128:], chunk=64,
                         initial_state=st)
    full = jnp.concatenate([h1, h2], axis=2)
    np.testing.assert_allclose(np.asarray(full), np.asarray(hr),
                               rtol=2e-3, atol=2e-3)
