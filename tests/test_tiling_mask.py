"""Property tests for the tiling-mask strategy (T2): the (2M)^2 M-mask
must reconstruct ANY causal / banded B-mask exactly (paper Fig. 3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import tiling_mask as tm


@settings(max_examples=200, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    bq=st.integers(1, 32),
    bk=st.integers(1, 32),
    q0=st.integers(0, 256),
    k0=st.integers(0, 256),
)
def test_bmask_equals_dense_slice(m, bq, bk, q0, k0):
    bq = min(bq, m)
    bk = min(bk, m)
    cls = tm.classify_block(q0, k0, bq, bk, causal=True)
    dense = np.asarray(tm.dense_mask(bq, bk, causal=True,
                                     q_offset=q0 - k0))  # delta semantics
    # dense_mask(q_offset=q0) compares (q0+r >= c); block mask compares
    # (q0+r >= k0+c) == ((q0-k0)+r >= c)
    if cls == tm.SKIP:
        assert not dense.any()
        return
    if cls == tm.FULL:
        assert dense.all()
        return
    mm = tm.make_m_mask(m)
    bm = np.asarray(tm.slice_bmask(mm, q0 - k0, bq, bk)) != 0
    np.testing.assert_array_equal(bm, dense)


@settings(max_examples=150, deadline=None)
@given(
    m=st.sampled_from([16, 32]),
    bq=st.integers(1, 16),
    bk=st.integers(1, 16),
    q0=st.integers(0, 128),
    k0=st.integers(0, 128),
    window=st.integers(1, 64),
)
def test_band_bmask_equals_dense(m, bq, bk, q0, k0, window):
    bq = min(bq, m)
    bk = min(bk, m)
    cls = tm.classify_block(q0, k0, bq, bk, causal=True, window=window)
    dense = np.asarray(tm.dense_mask(bq, bk, causal=True, window=window,
                                     q_offset=q0 - k0))
    if cls == tm.SKIP:
        assert not dense.any()
        return
    if cls == tm.FULL:
        assert dense.all()
        return
    mm = tm.make_m_mask(m)
    bm = np.asarray(tm.slice_band_bmask(mm, q0 - k0, window, bq, bk)) != 0
    np.testing.assert_array_equal(bm, dense)


@settings(max_examples=100, deadline=None)
@given(s=st.integers(1, 4096))
def test_memory_savings(s):
    """M-mask memory is independent of sequence length (paper: 8GB->256KB)."""
    assert tm.m_mask_memory_bytes(512) == (1024 * 1024)
    if s >= 1024:
        assert tm.mask_memory_bytes(s) > tm.m_mask_memory_bytes(512)


def test_block_limits_cover_exactly_the_visible_blocks():
    spec = tm.MaskSpec(causal=True, window=100)
    first, last = spec.block_limits(8, 8, 64, 64, kv_len=512)
    for qi in range(8):
        for ki in range(8):
            cls = tm.classify_block(qi * 64, ki * 64, 64, 64, causal=True,
                                    window=100, kv_len=512)
            inside = first[qi] <= ki <= last[qi]
            if cls != tm.SKIP:
                assert inside, (qi, ki)


def test_paper_memory_table():
    # paper: S=64K fp16 mask = 8 GB; M=512 M-mask = 256 KB (as 2-bit) --
    # we store int8: 1 MiB, still a 8192x reduction
    assert tm.mask_memory_bytes(65536, 2) == 8 * 2 ** 30
    assert tm.m_mask_memory_bytes(512, 1) == 2 ** 20
