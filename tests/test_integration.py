"""End-to-end integration: short training runs that must reduce loss,
checkpoint-resume exactness, serving generation, offload engine serving."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ParallelConfig, ServeConfig, TrainConfig,
                          get_model_config, reduce_for_smoke)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build_model
from repro.serving.engine import ServeEngine
from repro.training.checkpoint import CheckpointManager
from repro.training.train_step import init_train_state, make_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_training_reduces_loss():
    cfg = reduce_for_smoke(get_model_config("stablelm-3b"))
    parallel = ParallelConfig(remat="none")
    model = build_model(cfg, parallel)
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=30, warmup_steps=3)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, cfg, parallel, tcfg))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.next().items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_checkpoint_resume_is_exact(tmp_path):
    cfg = reduce_for_smoke(get_model_config("xlstm-125m"))
    parallel = ParallelConfig(remat="none")
    model = build_model(cfg, parallel)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=20, warmup_steps=2)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4))
    step = jax.jit(make_train_step(model, cfg, parallel, tcfg))

    # run 1: 6 steps, checkpoint at 3
    mgr = CheckpointManager(str(tmp_path))
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(6):
        if i == 3:
            mgr.save(3, state, extras={"data": data.state()})
        batch = {k: jnp.asarray(v) for k, v in data.next().items()}
        state, m = step(state, batch)
    loss_direct = float(m["loss"])

    # run 2: restore at 3, replay
    state2 = init_train_state(model, jax.random.PRNGKey(0))
    state2, manifest = mgr.restore(state2)
    data2 = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                     global_batch=4))
    data2.restore(manifest["extras"]["data"])
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data2.next().items()}
        state2, m2 = step(state2, batch)
    assert abs(float(m2["loss"]) - loss_direct) < 1e-5


def test_serving_generates_and_is_greedy_deterministic():
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, cfg=cfg,
                         serve=ServeConfig(max_seq_len=64, top_k=1))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    out1 = engine.generate(toks, 8)
    engine2 = ServeEngine(model=model, params=params, cfg=cfg,
                          serve=ServeConfig(max_seq_len=64, top_k=1))
    out2 = engine2.generate(toks, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_train_driver_cli_smoke(tmp_path):
    """The actual launch script end to end (30 steps, reduced arch)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-125m",
         "--smoke", "--steps", "12", "--batch", "4", "--seq", "64",
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "6"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done" in out.stdout
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 12
