"""flash_decode kernel vs oracle across lengths/windows/GQA, and the
paged variant vs the dense reference through scrambled page tables."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fastattention import fast_attention_decode
from repro.kernels.flash_decode.kernel import (flash_decode_fwd,
                                               paged_flash_decode_fwd)
from repro.kernels.flash_decode.ref import paged_gather
from repro.kernels.fastattn.ref import decode_reference

CASES = [
    (2, 10, 2, 1024, 64, [1000, 321], None, None),
    (2, 4, 4, 512, 64, [512, 77], None, None),
    (2, 8, 2, 1024, 64, [900, 400], 256, None),
    (1, 4, 1, 512, 32, [511], None, 30.0),
    (3, 2, 1, 64, 16, [1, 33, 64], None, None),
    (1, 16, 2, 2048, 128, [2048], 512, None),
]


@pytest.mark.parametrize("case", CASES)
def test_decode_kernel(case):
    b, hq, hkv, s, d, lens, window, softcap = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kv_len = jnp.asarray(lens, jnp.int32)
    ref = decode_reference(q, k, v, kv_len, window=window,
                           softcap=softcap)[:, :, 0]
    out = flash_decode_fwd(q[:, :, 0], k, v, kv_len, window=window,
                           softcap=softcap, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def _paginate(dense, page_size, rng, table=None):
    """Scatter a dense (B, Hkv, S, D) cache into page pools behind a
    scrambled page table (pages of different sequences interleaved in the
    pool, plus unused garbage pages)."""
    b, hkv, s, d = dense.shape
    n_kv = s // page_size
    num_pages = b * n_kv + 4                      # spare pages stay garbage
    if table is None:
        table = rng.permutation(np.arange(1, num_pages))[:b * n_kv]
        table = table.reshape(b, n_kv).astype(np.int32)
    table = np.asarray(table)
    pools = rng.normal(size=(hkv, num_pages, page_size, d))  # garbage fill
    for bi in range(b):
        for ki in range(n_kv):
            pools[:, table[bi, ki]] = \
                dense[bi, :, ki * page_size:(ki + 1) * page_size]
    return jnp.asarray(pools, jnp.float32), jnp.asarray(table)


# (b, hq, hkv, s, d, lens, window, softcap) -- ragged GQA + window + softcap
PAGED_CASES = [
    (3, 8, 2, 512, 64, [500, 129, 512], None, None),
    (2, 4, 4, 256, 64, [256, 1], None, None),
    (2, 8, 2, 512, 64, [480, 200], 128, None),
    (2, 4, 1, 256, 32, [255, 77], None, 30.0),
    (2, 16, 2, 512, 128, [384, 511], 200, 25.0),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_matches_reference(case):
    b, hq, hkv, s, d, lens, window, softcap = case
    page_size = 128
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)), jnp.float32)
    dense_k = rng.normal(size=(b, hkv, s, d))
    dense_v = rng.normal(size=(b, hkv, s, d))
    kv_len = jnp.asarray(lens, jnp.int32)
    k_pages, table = _paginate(dense_k, page_size, rng)
    v_pages, _ = _paginate(dense_v, page_size, rng, table=table)

    ref = decode_reference(q, jnp.asarray(dense_k, jnp.float32),
                           jnp.asarray(dense_v, jnp.float32), kv_len,
                           window=window, softcap=softcap)[:, :, 0]
    out = paged_flash_decode_fwd(q[:, :, 0], k_pages, v_pages, table,
                                 kv_len, window=window, softcap=softcap,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    # the jittable gather-reference path agrees too
    out_ref = fast_attention_decode(
        q.transpose(0, 2, 1, 3), k_pages, v_pages, kv_len, window=window,
        softcap=softcap, impl="paged_reference", page_table=table)
    np.testing.assert_allclose(
        np.asarray(out_ref.transpose(0, 2, 1, 3)[:, :, 0]),
        np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_paged_gather_roundtrip():
    rng = np.random.default_rng(3)
    dense = rng.normal(size=(2, 2, 256, 32))
    pages, table = _paginate(dense, 128, rng)
    got = paged_gather(pages, table)
    np.testing.assert_allclose(np.asarray(got), dense, rtol=1e-6,
                               atol=1e-6)


def test_paged_facade_matches_dense_reference_impl():
    """fast_attention_decode(impl="paged") == impl="reference" on the
    same logical cache, ragged GQA batch."""
    b, hq, hkv, s, d, page_size = 3, 8, 2, 384, 64, 128
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    dense_k = rng.normal(size=(b, hkv, s, d))
    dense_v = rng.normal(size=(b, hkv, s, d))
    kv_len = jnp.asarray([384, 129, 17], jnp.int32)
    k_pages, table = _paginate(dense_k, page_size, rng)
    v_pages, _ = _paginate(dense_v, page_size, rng, table=table)
    ref = fast_attention_decode(
        q, jnp.asarray(dense_k.transpose(0, 2, 1, 3), jnp.float32),
        jnp.asarray(dense_v.transpose(0, 2, 1, 3), jnp.float32), kv_len,
        impl="reference")
    out = fast_attention_decode(q, k_pages, v_pages, kv_len,
                                impl="paged", page_table=table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_paged_requires_page_table():
    q = jnp.zeros((1, 1, 4, 32), jnp.float32)
    pages = jnp.zeros((2, 4, 128, 32), jnp.float32)
    with pytest.raises(ValueError, match="page_table"):
        fast_attention_decode(q, pages, pages,
                              jnp.asarray([1], jnp.int32), impl="paged")


def test_decode_block_size_invariance():
    rng = np.random.default_rng(1)
    b, hq, hkv, s, d = 2, 8, 2, 768, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kv_len = jnp.asarray([700, 123], jnp.int32)
    outs = [flash_decode_fwd(q, k, v, kv_len, block_kv=bk, interpret=True)
            for bk in (128, 256, 768)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)
