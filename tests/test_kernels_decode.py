"""flash_decode kernel vs oracle across lengths/windows/GQA."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.kernel import flash_decode_fwd
from repro.kernels.fastattn.ref import decode_reference

CASES = [
    (2, 10, 2, 1024, 64, [1000, 321], None, None),
    (2, 4, 4, 512, 64, [512, 77], None, None),
    (2, 8, 2, 1024, 64, [900, 400], 256, None),
    (1, 4, 1, 512, 32, [511], None, 30.0),
    (3, 2, 1, 64, 16, [1, 33, 64], None, None),
    (1, 16, 2, 2048, 128, [2048], 512, None),
]


@pytest.mark.parametrize("case", CASES)
def test_decode_kernel(case):
    b, hq, hkv, s, d, lens, window, softcap = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kv_len = jnp.asarray(lens, jnp.int32)
    ref = decode_reference(q, k, v, kv_len, window=window,
                           softcap=softcap)[:, :, 0]
    out = flash_decode_fwd(q[:, :, 0], k, v, kv_len, window=window,
                           softcap=softcap, block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=2e-5)


def test_decode_block_size_invariance():
    rng = np.random.default_rng(1)
    b, hq, hkv, s, d = 2, 8, 2, 768, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kv_len = jnp.asarray([700, 123], jnp.int32)
    outs = [flash_decode_fwd(q, k, v, kv_len, block_kv=bk, interpret=True)
            for bk in (128, 256, 768)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)
