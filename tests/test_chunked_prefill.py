"""Chunked paged prefill: exact equivalence vs the scan-prefill oracle,
trace-count independence from prompt length, and sampling regressions.

The scan path teacher-forces the prompt token-by-token through
``decode_step_paged`` (PR 1's prefill, retraced per prompt length); the
chunked path pushes fixed-size chunks through the full forward with
runtime position offsets.  Greedy tokens must match bit-for-bit across
GQA / sliding-window / softcap configs and ragged prompt lengths --
including prompts that are not a multiple of the chunk or the page size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.serving.engine import ServeEngine, sample_token
from repro.serving.scheduler import FINISHED, Request

# gemma2: GQA + alternating sliding-window blocks + attn logit softcap;
# qwen2.5: plain GQA with qkv bias -- together they cover the feature grid
ARCHS = ["gemma2-2b", "qwen2.5-32b"]


@pytest.fixture(scope="module", params=ARCHS)
def engine_factory(request):
    from repro.models import build_model
    cfg = reduce_for_smoke(get_model_config(request.param))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))

    def make(serve):
        return ServeEngine(model=model, params=params, cfg=cfg,
                           serve=serve), cfg
    return make


def _run(engine, cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab_size, size=s),
                    max_new_tokens=n) for i, (s, n) in enumerate(spec)]
    list(engine.generate_stream(reqs))
    assert all(r.state == FINISHED for r in reqs)
    return [r.generated for r in reqs]


# ragged prompt lengths: 5 < page, 16 == page, 17 crosses a page, 37 is
# neither a chunk nor a page multiple, 51 needs 4 pages and 3 chunks
SPEC = [(5, 4), (16, 3), (17, 4), (37, 3), (51, 2)]


def test_chunked_matches_scan_exact(engine_factory):
    """Chunked paged prefill must produce bit-identical greedy tokens to
    the PR 1 scan prefill on mixed ragged-length traffic."""
    kw = dict(max_batch=3, max_seq_len=96, top_k=1, page_size=16,
              prefill_chunk=16)
    engine, cfg = engine_factory(ServeConfig(prefill_mode="scan", **kw))
    scan_tokens = _run(engine, cfg, SPEC)
    engine, cfg = engine_factory(ServeConfig(prefill_mode="chunked", **kw))
    chunk_tokens = _run(engine, cfg, SPEC)
    assert chunk_tokens == scan_tokens


def test_chunked_matches_scan_odd_chunk_and_budget(engine_factory):
    """Chunk size not a page multiple + a tiny per-step budget (maximum
    interleaving) must not change any token either."""
    kw = dict(max_batch=2, max_seq_len=96, top_k=1, page_size=16)
    engine, cfg = engine_factory(ServeConfig(prefill_mode="scan", **kw))
    scan_tokens = _run(engine, cfg, SPEC, seed=1)
    engine, cfg = engine_factory(ServeConfig(
        prefill_mode="chunked", prefill_chunk=12, prefill_token_budget=1,
        **kw))
    chunk_tokens = _run(engine, cfg, SPEC, seed=1)
    assert chunk_tokens == scan_tokens


def test_trace_count_independent_of_prompt_length(engine_factory):
    """The jitted chunk function must trace a bounded number of times --
    one per power-of-two launch width up to max_batch (here 2), NEVER
    per prompt length (the scan path retraces per length -- the
    compile-time cost the chunked path removes)."""
    engine, cfg = engine_factory(ServeConfig(
        max_batch=2, max_seq_len=96, top_k=1, page_size=16,
        prefill_chunk=16))
    engine.prefill_trace_count = 0
    engine._paged_fn_cache.clear()
    spec = [(5, 2), (23, 2), (37, 2), (64, 2), (41, 2)]
    _run(engine, cfg, spec)
    assert engine.prefill_trace_count <= 2          # widths 1 and 2
    # streaming MORE distinct prompt lengths adds no traces
    traced = engine.prefill_trace_count
    _run(engine, cfg, [(7, 2), (29, 2), (53, 2), (61, 2)], seed=5)
    assert engine.prefill_trace_count == traced


def test_chunked_prefill_kernel_impl_matches_reference(engine_factory):
    """The Pallas paged-prefill kernel (interpret mode) must produce the
    same greedy tokens as the gather-reference path, end to end through
    the engine."""
    kw = dict(max_batch=2, max_seq_len=64, top_k=1, page_size=16,
              prefill_chunk=16)
    spec = [(21, 3), (7, 2)]
    engine, cfg = engine_factory(ServeConfig(
        paged_impl="paged_reference", **kw))
    ref_tokens = _run(engine, cfg, spec, seed=2)
    engine, cfg = engine_factory(ServeConfig(
        paged_impl="paged_interpret", **kw))
    ker_tokens = _run(engine, cfg, spec, seed=2)
    assert ker_tokens == ref_tokens


def test_top_k_clamped_to_vocab():
    """top_k > vocab must sample (clamped) instead of crashing lax.top_k,
    and behave exactly like top_k == vocab."""
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 11)),
                         jnp.float32)
    key = jax.random.PRNGKey(0)
    big = sample_token(logits, key, top_k=1000)
    full = sample_token(logits, key, top_k=11)
    assert big.shape == (2,)
    np.testing.assert_array_equal(np.asarray(big), np.asarray(full))
    assert int(big.max()) < 11
