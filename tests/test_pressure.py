"""Page-pressure subsystem tests: preemption, swap-to-host, recompute.

Unit level: victim selection is newest-first, preemption leaks no pages,
resumed requests re-admit FIFO ahead of fresh arrivals, the swap
gather/scatter round trip is bit-exact, and the auto policy flips from
recompute to swap with the victim's KV volume.  System level: with the
pool sized to ~60% of a mixed-length workload's worst-case demand, every
request completes and greedy tokens are bit-identical to an unpressured
(large-pool) run under both ``preempt_policy="swap"`` and
``"recompute"`` -- no OutOfPages ever reaches the caller.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.core.offload import OffloadLatencyModel, preempt_cost_model
from repro.layers.attention import KVCache
from repro.serving.paged_cache import OutOfPages, PagedKVCache
from repro.serving.pressure import (HostPagePool, PressureManager,
                                    gather_pages, scatter_pages)
from repro.serving.scheduler import (FINISHED, PREEMPTED,
                                     ContinuousBatchScheduler, Request)


def _req(i, prompt_len, max_new, vocab=256):
    rng = np.random.default_rng(i)
    return Request(id=i, prompt=rng.integers(0, vocab, size=prompt_len),
                   max_new_tokens=max_new)


def _fake_pools(num_pages, page_size, seed=0):
    """A pools pytree shaped like LM.init_paged_cache: one plain 4-D
    leaf pair and one lax.scan-stacked 5-D pair."""
    rng = np.random.default_rng(seed)

    def arr(shape):
        return jnp.asarray(rng.normal(size=shape), jnp.float32)

    return {
        "seg0": {"u0": KVCache(k=arr((2, num_pages, page_size, 3)),
                               v=arr((2, num_pages, page_size, 3)))},
        "seg1": {"u0": KVCache(k=arr((2, 2, num_pages, page_size, 3)),
                               v=arr((2, 2, num_pages, page_size, 3)))},
    }


# ---------------------------------------------------------------------------
# unit: swap data path
# ---------------------------------------------------------------------------

def test_gather_scatter_roundtrip_exact():
    """Swapping pages out and back -- even into DIFFERENT physical pages
    -- must reproduce the page contents bit-for-bit."""
    pools = _fake_pools(num_pages=8, page_size=4)
    out_pages, in_pages, keep = [5, 2, 7], [1, 6, 3], [0, 4]
    # snapshot expectations BEFORE scatter: on non-CPU backends the
    # scatter donates (invalidates) the input pools
    expect_moved = gather_pages(pools, out_pages)
    expect_keep = gather_pages(pools, keep)
    host = gather_pages(pools, out_pages)
    restored = scatter_pages(pools, in_pages, host)
    got_moved = gather_pages(restored, in_pages)
    got_keep = gather_pages(restored, keep)     # untouched pages intact
    for want, got in ((expect_moved, got_moved), (expect_keep, got_keep)):
        for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_array_equal(w, g)


def test_host_page_pool_accounting():
    hp = HostPagePool(capacity_pages=4)
    assert hp.has_room(4) and not hp.has_room(5)
    hp.put(0, {"x": np.zeros(3)}, 3)
    assert hp.used_pages == 3 and 0 in hp
    assert not hp.has_room(2)
    with pytest.raises(OutOfPages):
        hp.put(1, {"x": np.zeros(2)}, 2)
    hp.pop(0)
    assert hp.used_pages == 0 and hp.peak_pages == 3 and 0 not in hp
    unbounded = HostPagePool(0)
    assert unbounded.has_room(10 ** 9)


# ---------------------------------------------------------------------------
# unit: victim selection / scheduler interaction
# ---------------------------------------------------------------------------

def _sched_with_pressure(policy="recompute", num_pages=12, page_size=4,
                         max_slots=3, host_pool_pages=0, lat=None):
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    cache = PagedKVCache(num_pages=num_pages, page_size=page_size,
                         max_slots=max_slots, max_pages_per_seq=8)
    sched = ContinuousBatchScheduler(cache, admission="optimistic",
                                     watermark_pages=1)
    serve = ServeConfig(preempt_policy=policy,
                        host_pool_pages=host_pool_pages,
                        page_size=page_size)
    pressure = PressureManager(cfg, serve, cache, sched,
                               latency_model=lat)
    return cache, sched, pressure


def test_victim_is_newest_admitted_and_no_leak():
    cache, sched, pressure = _sched_with_pressure()
    reqs = [_req(i, 4, 8) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    assert len(sched.admit()) == 3
    for slot in range(3):
        cache.append(slot, 6)                    # 2 pages each
        sched.slots[slot].prefilled = 4
    cache.check_invariants()
    free_before = cache.free_pages

    victim = pressure.relieve(pools=None, protect=0)
    assert victim is reqs[2]                     # newest admission
    assert victim.state == PREEMPTED and victim.slot is None
    assert victim.preemptions == 1
    assert cache.free_pages == free_before + 2   # its pages came back
    cache.check_invariants()

    # next relief (still protecting 0) evicts the next-newest
    assert pressure.relieve(pools=None, protect=0) is reqs[1]
    assert pressure.stats["preemptions"] == 2
    assert pressure.stats["recomputes"] == 2
    cache.check_invariants()

    # only the protected slot remains: no further victim
    with pytest.raises(OutOfPages):
        pressure.relieve(pools=None, protect=0)


def test_resumed_requests_readmit_fifo_ahead_of_waiting():
    cache, sched, pressure = _sched_with_pressure()
    reqs = [_req(i, 4, 8) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    sched.admit()
    for slot in range(3):
        sched.slots[slot].prefilled = 4
    # evict newest-first: 2 then 1 -- the resuming queue must hold them
    # oldest arrival first
    pressure.relieve(pools=None, protect=0)
    pressure.relieve(pools=None, protect=0)
    assert [r.id for r in sched.resuming] == [1, 2]

    sched.submit(_req(9, 4, 8))                  # fresh arrival
    admitted = sched.admit()
    # preempted requests go ahead of the waiting queue, FIFO
    assert [r.id for _, r in admitted] == [1, 2]
    assert [r.id for r in sched.waiting] == [9]
    cache.check_invariants()


def test_preemption_of_prefilling_sequence_restarts_prefill():
    """A victim that had completed 1 of 2 prompt pages resumes as a
    recompute with prefilled reset at re-admission."""
    cache, sched, pressure = _sched_with_pressure()
    a, b = _req(0, 4, 8), _req(1, 8, 8)
    sched.submit(a)
    sched.submit(b)
    sched.admit()
    cache.append(1, 4)                           # b: first chunk done
    b.prefilled = 4
    victim = pressure.relieve(pools=None, protect=0)
    assert victim is b and b.resume_kind == "recompute"
    assert b.resume_len == 4
    [(slot, readmitted)] = [x for x in sched.admit() if x[1] is b]
    assert readmitted.prefilled == 0             # recompute from scratch
    assert cache.seq_len(slot) == 0
    cache.check_invariants()


# ---------------------------------------------------------------------------
# unit: swap/recompute policy
# ---------------------------------------------------------------------------

def test_cost_model_crossover_small_recomputes_large_swaps():
    """Fixed PCIe latency dominates tiny victims (recompute); re-prefill
    FLOPs dominate long-context victims (swap)."""
    cfg = get_model_config("gemma2-2b")
    lat = OffloadLatencyModel()
    kw = dict(page_size=128, model=lat, swap_latency_s=5e-4)
    s_small, r_small = preempt_cost_model(cfg, n_pages=1, n_tokens=16, **kw)
    s_big, r_big = preempt_cost_model(
        cfg, n_pages=512, n_tokens=512 * 128, **kw)
    assert r_small < s_small                     # tiny victim: recompute
    assert s_big < r_big                         # long context: swap
    # monotone in volume
    assert s_big > s_small and r_big > r_small


def test_auto_policy_uses_cost_model_and_host_capacity():
    # a latency model where PCIe is free makes swap always win...
    fast_pcie = OffloadLatencyModel(pcie_gbps=1e12, device_tflops=1e-3)
    cache, sched, pressure = _sched_with_pressure(policy="auto",
                                                  lat=fast_pcie)
    pressure.swap_latency_s = 0.0
    assert pressure.choose_policy(n_pages=2, n_tokens=6) == "swap"
    # ...a model where the device is infinitely fast makes recompute win
    fast_dev = OffloadLatencyModel(pcie_gbps=1e-3, device_tflops=1e12)
    pressure.lat = fast_dev
    assert pressure.choose_policy(n_pages=2, n_tokens=6) == "recompute"
    # zero materialised KV is always a recompute (nothing to move)
    assert pressure.choose_policy(n_pages=0, n_tokens=0) == "recompute"


def test_full_host_pool_downgrades_swap_to_recompute():
    cache, sched, pressure = _sched_with_pressure(policy="swap",
                                                  host_pool_pages=1)
    reqs = [_req(i, 8, 8) for i in range(2)]
    for r in reqs:
        sched.submit(r)
    sched.admit()
    for slot in range(2):
        cache.append(slot, 8)                    # 2 pages each
        sched.slots[slot].prefilled = 8
    pools = _fake_pools(num_pages=12, page_size=4)
    victim = pressure.preempt_slot(pools, 1)
    # 2 pages > host capacity 1: forced recompute, nothing stashed
    assert victim.resume_kind == "recompute"
    assert pressure.stats["recomputes"] == 1 and len(pressure.host_pool) == 0
    cache.check_invariants()


# ---------------------------------------------------------------------------
# system: pressured serving is bit-identical to unpressured
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine():
    from repro.models import build_model
    from repro.serving.engine import ServeEngine
    cfg = reduce_for_smoke(get_model_config("gemma2-2b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))

    def make(serve):
        return ServeEngine(model=model, params=params, cfg=cfg,
                           serve=serve), cfg
    return make


# mixed lengths; no eos, so every sequence realises its worst case and
# concurrent demand (4 slots x up to 4 pages) exceeds the pressured pool
PRESSURE_SPEC = [(8, 56), (5, 43), (20, 44), (4, 44), (30, 34), (6, 58)]
WORST_PAGES = sum(-(-(s + n) // 16) for s, n in PRESSURE_SPEC)   # 22


def _run_spec(engine, cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab_size, size=s),
                    max_new_tokens=n) for i, (s, n) in enumerate(spec)]
    events = list(engine.generate_stream(reqs))
    assert all(r.state == FINISHED for r in reqs)
    assert len(events) == sum(n for _, n in spec)
    return [r.generated for r in reqs]


@pytest.mark.parametrize("policy", ["swap", "recompute", "auto"])
def test_pressured_tokens_bit_identical_to_unpressured(tiny_engine, policy):
    """Pool at ~60% of worst-case demand: every request completes, no
    OutOfPages escapes, and greedy tokens match the large-pool run."""
    kw = dict(max_batch=4, max_seq_len=64, top_k=1, page_size=16,
              debug_invariants=True)
    engine, cfg = tiny_engine(ServeConfig(num_pages=0, **kw))   # unpressured
    oracle = _run_spec(engine, cfg, PRESSURE_SPEC)
    assert engine.last_pressure.stats["preemptions"] == 0

    pool = int(WORST_PAGES * 0.6) + 1            # 13 usable pages
    engine, cfg = tiny_engine(ServeConfig(
        num_pages=pool, preempt_policy=policy, **kw))
    tokens = _run_spec(engine, cfg, PRESSURE_SPEC)
    assert tokens == oracle

    mgr, pressure = engine.last_cache, engine.last_pressure
    assert pressure.stats["preemptions"] > 0, "pool never pressured"
    if policy == "swap":
        assert pressure.stats["swaps"] == pressure.stats["preemptions"]
        assert pressure.stats["swap_bytes_in"] == \
            pressure.stats["swap_bytes_out"] > 0
    if policy == "recompute":
        assert pressure.stats["recomputes"] == pressure.stats["preemptions"]
    assert len(pressure.host_pool) == 0, "stash leaked"
    assert mgr.used_pages == 0, "pages leaked after drain"
    assert mgr.peak_used_pages <= pool - 1, "pool ceiling violated"
    assert mgr.peak_utilization > 0.8, "pressured pool under-used"


def test_pressured_scan_prefill_mode_also_exact(tiny_engine):
    """The scan-prefill oracle path survives preemption too (whole
    re-prefill source in one scan)."""
    kw = dict(max_batch=4, max_seq_len=64, top_k=1, page_size=16)
    spec = PRESSURE_SPEC[:4]
    engine, cfg = tiny_engine(ServeConfig(num_pages=0, prefill_mode="scan",
                                          **kw))
    oracle = _run_spec(engine, cfg, spec, seed=3)
    engine, cfg = tiny_engine(ServeConfig(
        num_pages=10, prefill_mode="scan", preempt_policy="swap", **kw))
    assert _run_spec(engine, cfg, spec, seed=3) == oracle
    assert engine.last_pressure.stats["preemptions"] > 0


def test_reserved_admission_never_preempts(tiny_engine):
    """The baseline policy on the same pressured pool must queue instead
    of preempting -- and still finish with identical tokens."""
    kw = dict(max_batch=4, max_seq_len=64, top_k=1, page_size=16)
    pool = int(WORST_PAGES * 0.6) + 1
    engine, cfg = tiny_engine(ServeConfig(
        num_pages=pool, admission="reserved", **kw))
    tokens = _run_spec(engine, cfg, PRESSURE_SPEC, seed=0)
    assert engine.last_pressure.stats["preemptions"] == 0
    engine, cfg = tiny_engine(ServeConfig(num_pages=0, **kw))
    assert tokens == _run_spec(engine, cfg, PRESSURE_SPEC, seed=0)
