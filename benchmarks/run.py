"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  This container is CPU-only,
so wall-times are CPU-scaled (shapes reduced, same algorithmic structure);
`derived` carries the paper-comparable quantity (speedup ratio, memory
saving, collective count, max context) which is shape- and
hardware-portable.  See EXPERIMENTS.md for the TPU-target roofline view.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table3]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6     # us


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# Figure 7: FastAttention operator vs standard attention (PanGu dims)
# ---------------------------------------------------------------------------

def bench_fig7_operator_speedup():
    from repro.kernels.fastattn.ref import flash_reference, \
        standard_attention
    rng = np.random.default_rng(0)
    # paper Sec 5.2.1: B=1, N=5 heads (PanGu-38B TP slice), D=128
    for name, heads in (("pangu38b", 5), ("pangu71b", 4)):
        for s in (1024, 2048, 4096):
            q = jnp.asarray(rng.normal(size=(1, heads, s, 128)),
                            jnp.float32)
            k, v = q + 0.1, q - 0.1
            std = jax.jit(lambda q, k, v: standard_attention(
                q, k, v, causal=True))
            fast = jax.jit(lambda q, k, v: flash_reference(
                q, k, v, causal=True, block_kv=1024))
            t_std = _timeit(std, q, k, v, n=3)
            t_fast = _timeit(fast, q, k, v, n=3)
            row(f"fig7_{name}_s{s}_standard", t_std, "")
            row(f"fig7_{name}_s{s}_fastattn", t_fast,
                f"speedup={t_std / t_fast:.2f}x")


# ---------------------------------------------------------------------------
# Figure 8: TFLOPs/s across sequence lengths, +-causal
# ---------------------------------------------------------------------------

def bench_fig8_tflops():
    from repro.kernels.fastattn.ref import flash_reference
    rng = np.random.default_rng(1)
    b, h, d = 2, 8, 32          # CPU-scaled from paper's B=8 H=64
    for causal in (False, True):
        for s in (1024, 2048, 4096):
            q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
            fast = jax.jit(lambda q: flash_reference(
                q, q, q, causal=causal, block_kv=512))
            us = _timeit(fast, q, n=3)
            flops = 4 * s * s * d * h * b * (0.5 if causal else 1.0)
            row(f"fig8_s{s}_causal{int(causal)}", us,
                f"gflops_per_s={flops / us / 1e3:.2f}")


# ---------------------------------------------------------------------------
# Figure 9: two-level tiling block-size sweep (latency vs level-1 size)
# ---------------------------------------------------------------------------

def bench_fig9_blocksize():
    from repro.kernels.fastattn.ref import flash_reference
    from repro.core.tiling import plan_two_level_tiling, sync_count
    rng = np.random.default_rng(2)
    s, h, d = 4096, 5, 128
    q = jnp.asarray(rng.normal(size=(1, h, s, d)), jnp.float32)
    base_us = None
    for bs in (128, 256, 512, 1024, 2048):
        fast = jax.jit(lambda q: flash_reference(q, q, q, causal=True,
                                                 block_kv=bs))
        us = _timeit(fast, q, n=3)
        if base_us is None:
            base_us = us
        red = 100 * (1 - us / base_us)
        row(f"fig9_bs{bs}", us,
            f"latency_reduction_vs_bs128={red:.1f}%;"
            f"syncs={sync_count(s, bs)}")
    plan = plan_two_level_tiling(s, s, d)
    row("fig9_planner_choice", 0,
        f"block_q={plan.block_q};block_kv1={plan.block_kv1};"
        f"block_kv2={plan.block_kv2};vmem_bytes={plan.vmem_bytes}")


# ---------------------------------------------------------------------------
# Table 2: ablation of the proposed strategies
# ---------------------------------------------------------------------------

def bench_table2_ablation():
    from repro.kernels.fastattn.ref import flash_reference, \
        standard_attention
    from repro.core.tiling import plan_two_level_tiling
    rng = np.random.default_rng(3)
    s, h, d = 2048, 5, 128
    q = jnp.asarray(rng.normal(size=(1, h, s, d)), jnp.float32)
    t_std = _timeit(jax.jit(lambda q: standard_attention(q, q, q,
                                                         causal=True)),
                    q, n=3)
    t_unified = _timeit(jax.jit(lambda q: flash_reference(
        q, q, q, causal=True, block_kv=128)), q, n=3)
    plan = plan_two_level_tiling(s, s, d)
    t_two = _timeit(jax.jit(lambda q: flash_reference(
        q, q, q, causal=True, block_kv=plan.block_kv1)), q, n=3)
    row("table2_standard", t_std, "speedup=1.00x")
    row("table2_unified_tiling", t_unified,
        f"speedup={t_std / t_unified:.2f}x")
    row("table2_two_level_tiling", t_two,
        f"speedup={t_std / t_two:.2f}x")
    # tiling-mask: memory + skipped-block accounting (arch-agnostic)
    from repro.core import tiling_mask as tm
    spec = tm.MaskSpec(causal=True)
    first, last = spec.block_limits(s // 128, s // 128, 128, 128, s)
    visited = int(np.sum(last - first + 1))
    total = (s // 128) ** 2
    row("table2_tiling_mask", 0,
        f"mask_mem={tm.m_mask_memory_bytes(256)}B_vs_"
        f"{tm.mask_memory_bytes(s)}B;cube_blocks_skipped="
        f"{100 * (1 - visited / total):.0f}%")


# ---------------------------------------------------------------------------
# Figures 10/16/17: tiling-AllReduce (T3) on an 8-device mesh
# ---------------------------------------------------------------------------

def bench_tiling_allreduce():
    code = r"""
import json, time, functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.tiled_allreduce import make_sharded_fused_block
from repro.analysis.hlo import analyze_hlo_text
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ('model',))
rng = np.random.default_rng(0)
b, s, h, d, dm = 1, 512, 40, 16, 640      # 40 heads / 8 = 5 per device
q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
wo = jnp.asarray(rng.normal(size=(h*d, dm)) * 0.05, jnp.float32)
out = {}
for mode, chunks in (('single', 1), ('tiled', 4), ('tiled8', 8)):
    f = make_sharded_fused_block(mesh, mode='tiled' if 'tiled' in mode
                                 else 'single',
                                 n_chunks=chunks, causal=True)
    jf = jax.jit(f)
    r = jf(q, q, q, wo); jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(3):
        r = jf(q, q, q, wo)
    jax.block_until_ready(r)
    us = (time.perf_counter() - t0) / 3 * 1e6
    cost = analyze_hlo_text(jf.lower(q, q, q, wo).compile().as_text())
    n_ar = sum(n for _, _, n in cost.top_collectives)
    out[mode] = dict(us=us, n_allreduce=n_ar,
                     coll_bytes=cost.collective_bytes)
f1 = jax.jit(make_sharded_fused_block(mesh, mode='single', causal=True))
f2 = jax.jit(make_sharded_fused_block(mesh, mode='tiled', n_chunks=4,
                                      causal=True))
err = float(jnp.max(jnp.abs(f1(q, q, q, wo) - f2(q, q, q, wo))))
out['max_err'] = err
print(json.dumps(out))
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        row("fig10_tiling_allreduce", 0, f"ERROR:{res.stderr[-200:]}")
        return
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for mode in ("single", "tiled", "tiled8"):
        r = out[mode]
        row(f"fig10_allreduce_{mode}", r["us"],
            f"n_allreduce={r['n_allreduce']};"
            f"coll_bytes={int(r['coll_bytes'])};"
            f"overlappable={'no' if mode == 'single' else 'yes'}")
    row("fig10_allreduce_equivalence", 0, f"max_err={out['max_err']:.2e}")


# ---------------------------------------------------------------------------
# Table 3: CPU-GPU cooperative strategy vs classical offloading
# ---------------------------------------------------------------------------

def bench_table3_offload():
    from repro.config import get_model_config
    from repro.core.offload import (max_context_length, table3_row)
    cfg = get_model_config("pangu-38b")
    for s in (1024, 16384, 65536, 262144):
        r = table3_row(cfg, s, device_memory_gb=16)
        if not r["offload"]:
            row(f"table3_s{s}", r["gpu_calc_s"] * 1e6, "offload=no")
        else:
            row(f"table3_s{s}_classical", r["classical_total_s"] * 1e6,
                f"upload_ms={r['classical_upload_s'] * 1e3:.2f}")
            row(f"table3_s{s}_cooperative", r["coop_total_s"] * 1e6,
                f"speedup={r['speedup']:.2f}x;l_cpu={r['l_cpu']};"
                f"l_gpu={r['l_gpu']}")
    mc = max_context_length(cfg, batch=1, n_devices=8, device_memory_gb=16,
                            host_memory_gb=768)
    row("table3_max_context", 0,
        f"device_only={mc['device_only']};"
        f"cooperative={mc['cooperative']};"
        f"extension={mc['cooperative'] / max(mc['device_only'], 1):.1f}x")
    # measured host-attention data path (engine smoke, CPU-real)
    from repro.core.offload import HostOffloadEngine, OffloadPlan
    cfg_s = get_model_config("whisper-small")
    plan = OffloadPlan(1, 1, 0, 0, 0, 0, 0, True)
    eng = HostOffloadEngine(cfg_s, plan, max_batch=1, max_seq=2048)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(1, 2048, cfg_s.num_kv_heads,
                                     cfg_s.head_dim)), jnp.float32)
    eng.prefill_offload(0, k, k)
    q = jnp.asarray(rng.normal(size=(1, 1, cfg_s.num_heads,
                                     cfg_s.head_dim)), jnp.float32)
    us = _timeit(lambda: eng.decode_attention(0, q, [2048]), n=3)
    row("table3_host_attention_measured", us, "kv_len=2048")


# ---------------------------------------------------------------------------
# Tables 4/5/6: end-to-end latency & throughput (reduced models)
# ---------------------------------------------------------------------------

def bench_e2e_throughput():
    from repro.config import (ParallelConfig, ServeConfig,
                              get_model_config, reduce_for_smoke)
    from repro.models import build_model
    from repro.serving.engine import ServeEngine
    cfg = reduce_for_smoke(get_model_config("llama2-7b"))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    for batch in (1, 4, 8):
        eng = ServeEngine(model=model, params=params, cfg=cfg,
                          serve=ServeConfig(max_seq_len=128))
        tps = eng.throughput_tokens_per_s(batch, 32, n_new=8)
        row(f"table6_llama2-7b_b{batch}", 1e6 / max(tps, 1e-9),
            f"tokens_per_s={tps:.1f}")


# ---------------------------------------------------------------------------
# Mask memory table (paper Sec 4.1 numbers, exact)
# ---------------------------------------------------------------------------

def bench_mask_memory():
    from repro.core import tiling_mask as tm
    for s in (16384, 65536, 262144):
        dense = tm.mask_memory_bytes(s, 2)
        mmask = tm.m_mask_memory_bytes(512, 1)
        row(f"maskmem_s{s}", 0,
            f"dense={dense / 2**30:.2f}GiB;mmask={mmask / 2**10:.0f}KiB;"
            f"saving={dense / mmask:.0f}x")


BENCHES = {
    "fig7": bench_fig7_operator_speedup,
    "fig8": bench_fig8_tflops,
    "fig9": bench_fig9_blocksize,
    "table2": bench_table2_ablation,
    "fig10": bench_tiling_allreduce,
    "table3": bench_table3_offload,
    "table6": bench_e2e_throughput,
    "maskmem": bench_mask_memory,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]()


if __name__ == "__main__":
    main()
