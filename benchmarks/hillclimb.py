"""Perf hillclimb driver: re-lower one dry-run cell under a variant and
diff the three roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --cell qwen2.5-32b:prefill_32k --variant gqa_grouped

Variants are named experiments (hypothesis -> change); each writes
results/hillclimb/<cell>__<variant>.json so EXPERIMENTS.md §Perf can cite
before/after numbers.  The process must be fresh per run (512-device flag),
hence this is a separate __main__.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "hillclimb")


def apply_variant(name: str):
    """Mutate global knobs for a named experiment.  Returns rule overrides
    and a description."""
    from repro.sharding.rules import default_rules
    import repro.kernels.fastattn.ref as ref_mod
    rules = default_rules()
    desc = name
    if name == "baseline":
        pass
    elif name == "no_seq_shard":
        # Megatron-style: activations full-seq, ff sharded instead
        rules["seq"] = None
        rules["kv_seq"] = None
    elif name == "kv_shard_heads":
        # decode: shard KV cache on heads instead of cache-seq
        rules["kv_seq"] = None
        rules["heads"] = "model"
    elif name == "flat_batch_decode":
        # decode: spread batch over (data, model) -- needs B % 256 == 0
        rules["batch"] = ("data", "model")
        rules["kv_seq"] = None
        rules["seq"] = None
    elif name == "gqa_grouped":
        _patch_gqa_grouped()
    elif name == "gqa_grouped_bigblock":
        _patch_gqa_grouped()
        _patch_block_kv(2048)
    elif name == "expert_local_dispatch":
        _patch_moe_local_dispatch()
    elif name == "remat_full":
        _patch_remat("full")
    elif name == "remat_none":
        _patch_remat("none")
    elif name == "kv_layout_bhsd":
        import repro.layers.attention as attn
        attn.KV_CACHE_LAYOUT = "bhsd"
    else:
        raise ValueError(name)
    return rules


def _patch_remat(policy: str):
    import dataclasses
    import repro.launch.dryrun as dr
    orig = dr.parallel_for_mesh

    def patched(mesh):
        return dataclasses.replace(orig(mesh), remat=policy)
    dr.parallel_for_mesh = patched


def _patch_block_kv(bk):
    from repro.core import fastattention as fa
    orig = fa.fast_attention

    def patched(q, k, v, **kw):
        kw["block_kv1"] = bk
        return orig(q, k, v, **kw)
    fa.fast_attention = patched
    import repro.layers.attention as attn
    attn.fast_attention = patched


def _patch_gqa_grouped():
    """Replace flash_reference with the grouped-GQA version (no KV head
    expansion: einsum carries the (Hkv, G) structure)."""
    import repro.kernels.fastattn.ref as R
    import jax.numpy as jnp

    def flash_grouped(q, k, v, *, causal=True, window=None, softcap=None,
                      scale=None, q_offset=0, kv_len=None, block_kv=512):
        b, hq, sq, d = q.shape
        hkv, skv = k.shape[1], k.shape[2]
        g = hq // hkv
        scale_ = scale if scale is not None else d ** -0.5
        qg = q.reshape(b, hkv, g, sq, d)
        block_kv = min(block_kv, skv)
        n_chunks = (skv + block_kv - 1) // block_kv
        if causal:
            n_chunks = min(n_chunks, (q_offset + sq - 1) // block_kv + 1)
        usable = n_chunks * block_kv
        pad_n = usable - skv
        kc, vc = k, v
        if pad_n > 0:
            kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad_n), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad_n), (0, 0)))
        kc = kc[:, :, :usable].reshape(b, hkv, n_chunks, block_kv, d
                                       ).transpose(2, 0, 1, 3, 4)
        vc = vc[:, :, :usable].reshape(b, hkv, n_chunks, block_kv, d
                                       ).transpose(2, 0, 1, 3, 4)
        q_pos = q_offset + jnp.arange(sq)
        eff = jnp.minimum(jnp.asarray(kv_len if kv_len is not None
                                      else skv), skv)

        def step(carry, inp):
            m_prev, l_prev, acc = carry
            j, k_j, v_j = inp
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_j,
                           preferred_element_type=jnp.float32) * scale_
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            kv_pos = j * block_kv + jnp.arange(block_kv)
            mask = jnp.ones((sq, block_kv), bool)
            if causal:
                mask = mask & (q_pos[:, None] >= kv_pos[None, :])
            if window is not None:
                mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
            maskb = mask[None, None, None] & \
                (kv_pos[None, None, None, None, :]
                 < jnp.asarray(eff).reshape(-1, 1, 1, 1, 1))
            s = jnp.where(maskb, s, R.NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, sq), R.NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
        acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                      (jnp.arange(n_chunks), kc, vc))
        l_safe = jnp.where(l == 0, 1.0, l)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        return out.reshape(b, hq, sq, d)

    def patched_flash_reference(q, k, v, **kw):
        return flash_grouped(q, k, v, **kw)

    R.flash_reference_grouped = flash_grouped
    # route the public op through the grouped version
    import repro.kernels.fastattn.ops as ops
    orig_fastattn = ops.fastattn

    def fastattn2(q, k, v, causal=True, window=None, softcap=None,
                  scale=None, q_offset=0, block_q=256, block_kv1=1024,
                  block_kv2=256, impl="reference"):
        if impl == "reference":
            return flash_grouped(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 q_offset=q_offset, block_kv=block_kv1)
        return orig_fastattn(q, k, v, causal, window, softcap, scale,
                             q_offset, block_q, block_kv1, block_kv2, impl)

    import repro.core.fastattention as fa

    def fast_attention2(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None, q_offset=0, impl="reference",
                        block_q=256, block_kv1=1024, block_kv2=256):
        out = fastattn2(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal, window, softcap,
                        scale, q_offset, block_q, block_kv1, block_kv2,
                        impl)
        return out.transpose(0, 2, 1, 3)

    fa.fast_attention = fast_attention2
    import repro.layers.attention as attn
    attn.fast_attention = fast_attention2


def _patch_moe_local_dispatch():
    """Constrain MoE dispatch tensors so the argsort/gather stays local to
    the data shard and only the expert-compute einsum crosses `model`."""
    import repro.layers.moe as moe
    from repro.sharding.rules import constrain as C
    orig = moe.apply_moe

    def patched(params, x, cfg, **kw):
        x = C(x, "batch", None, None)     # pin tokens data-local, seq whole
        return orig(params, x, cfg, **kw)
    moe.apply_moe = patched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch:shape, e.g. qwen2.5-32b:prefill_32k")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    arch, shape = args.cell.split(":")
    rules = apply_variant(args.variant)

    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                   out_dir=RESULTS, save_hlo=True, rules=rules,
                   tag=f"__{args.variant}")
    rf = rec.get("roofline", {})
    print(json.dumps({
        "cell": rec["cell"], "variant": args.variant,
        "status": rec["status"],
        "error": rec.get("error"),
        "compute_s": rf.get("compute_s"),
        "memory_s": rf.get("memory_s"),
        "collective_s": rf.get("collective_s"),
        "dominant": rf.get("dominant"),
        "by_collective": rf.get("by_collective"),
        "useful_ratio": rf.get("useful_ratio"),
    }, indent=1))


if __name__ == "__main__":
    main()
