"""Serving benchmark: paged KV + continuous batching + chunked prefill.

Three sections, emitted together as machine-readable
``BENCH_serving.json`` at the repo root (the perf baseline future PRs
regress against):

* **mixed traffic** -- streams a queue of requests with randomised
  prompt/generation lengths through ``ServeEngine.generate_stream`` and
  reports decode throughput, per-request time-to-first-token (TTFT)
  and page-pool pressure.  The pool is deliberately sized
  *below* ``max_batch * max_seq_len``: the scheduler trades a longer
  queue for a hard memory ceiling a dense static-batch engine cannot
  offer at all.
* **prefill** -- one long prompt through the legacy scan prefill (one
  decode step per token, PR 1) vs chunked paged prefill (fixed-size
  chunks through the full tiled forward), reporting prefill tokens/s and
  the chunked/scan speedup.
* **oversubscription** -- offered load deliberately exceeds the pool
  (every request realises its worst case; the pool holds ``pool_frac``
  of the total demand).  Runs the same workload under the PR 1
  worst-case-reservation admission and under optimistic admission with
  preemption (swap-to-host / recompute), reporting preemption counts,
  swap bytes and the pool high-water-mark: reservation leaves the pool
  under-subscribed, pressure-managed admission drives it to ~100% with
  zero caller-visible failures.
* **prefix_sharing** -- N requests share a long system prompt.  A cold
  engine (no prefix cache) prefills every prompt from token 0; a warm
  engine (``prefix_cache=True``, radix index seeded by a first run)
  shares the cached system-prompt pages copy-on-write and computes only
  each request's unique tail.  Reports prefill tokens computed, TTFT
  and pages resident both ways; greedy tokens must be bit-identical.
* **open_loop** -- drives ``EngineCore.step()`` directly under a
  deterministic (seeded) Poisson arrival schedule with mixed per-request
  ``SamplingParams`` (greedy and seeded temperature sampling): requests
  arrive *while* the engine runs, instead of all up front.  Reports
  TTFT and TPOT (time per output token) p50/p99 -- the latency numbers
  an iteration-level engine exists for.
* **degradation** -- over-offered Poisson load (arrivals faster than
  the engine drains) through ``EngineCore.step()``, unbounded vs
  bounded (``max_waiting`` + ``queue_policy="shed_oldest"`` +
  per-request ``deadline_ms``).  Reports shed rate, timed-out count and
  the *survivors'* TTFT/TPOT p99 both ways: load shedding must keep the
  survivor tail flat while the unbounded engine's queueing latency
  grows without bound.
* **observability** -- telemetry overhead: the open-loop workload driven
  twice through ``EngineCore.step()``, once with the metrics registry /
  lifecycle tracer / flight recorder enabled (``metrics=True``, the
  default) and once fully disabled, reporting best-of-N ms/step both
  ways and the on/off overhead ratio (CI gates it at <= 1.05).  The
  metrics-on run also exports the flight recorder's Chrome
  ``trace_event`` JSON as ``BENCH_serving_trace.json``.
* **distributed** -- tensor-parallel serving on a forced multi-device
  CPU mesh (a child process under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``): the paged
  engine sharded 2- and 4-way (kv-head groups x page-row sub-shards,
  partial attention merged via the LSE combination) must emit greedy
  tokens bit-identical to the single-device engine, and the section
  times the tp=4 engine under the paper's tiling-AllReduce (§4.2 T3)
  against the monolithic single-AllReduce baseline.

    PYTHONPATH=src python -m benchmarks.serving_bench \
        [--arch gemma2-2b] [--requests 12] [--prefill-len 512]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.models import build_model
from repro.serving.core import EngineCore
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, SamplingParams

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _small_config(cfg):
    """A 'small' (not unit-test-tiny) CPU config for the prefill timing:
    reduce_for_smoke is sized for test latency, and at that width the
    per-step overhead of the scan baseline masks the batching win the
    chunked path exists for.  Keeps GQA ratio / window / softcap."""
    cfg = reduce_for_smoke(cfg)
    kv = cfg.num_kv_heads
    heads = cfg.num_heads
    head_dim = 32
    return dataclasses.replace(
        cfg, num_layers=4, d_model=heads * head_dim, head_dim=head_dim,
        d_ff=4 * heads * head_dim if cfg.d_ff else 0, vocab_size=1024,
        window_size=128 if cfg.window_size else None)


def _warm(engine, cfg, serve, rng):
    """Compile everything the timed region will hit: the fused decode
    step, a multi-chunk prompt, and every power-of-two batched-prefill
    launch width up to max_batch (w concurrent short prompts prefill in
    one step -> one width-w launch).  The engine core is persistent, so
    the serving state (peak pages, pressure stats, any prefix blocks
    the warmup published) is reset afterwards -- the reported metrics
    must cover only the timed workload; jit caches survive the reset."""
    widths, w = [], 1
    while w < serve.max_batch:
        widths.append(w)
        w *= 2
    widths.append(serve.max_batch)
    wid = -1
    for w in widths:
        warms = []
        for i in range(w):
            wid -= 1
            n = min(serve.prefill_chunk_tokens + 1,
                    serve.max_seq_len - 2) if (w == 1 and i == 0) else 3 + i
            warms.append(Request(id=wid, prompt=rng.integers(
                0, cfg.vocab_size, size=n), max_new_tokens=2))
        list(engine.generate_stream(warms))
    engine.core.reset()
    # open a fresh metrics window too: registry counters, the step-time
    # high water and the flight recorder survive reset() (they are
    # engine-lifetime, like the jit caches) and would otherwise report
    # warmup compile steps as part of the timed workload
    engine.core.reset_metrics_window()


def _build(arch: str, smoke: bool, small: bool = False):
    cfg = get_model_config(arch)
    if small and smoke:
        cfg = _small_config(cfg)
    elif smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def run(arch: str = "gemma2-2b", n_requests: int = 12, max_batch: int = 4,
        page_size: int = 0, max_seq_len: int = 128, pool_frac: float = 0.6,
        seed: int = 0, smoke: bool = True, built=None) -> dict:
    """Mixed-length-traffic section."""
    # 0 = auto: the TPU kernel needs lane-width (128) pages; CPU smoke
    # runs use small pages so slot/page churn actually happens
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    max_seq_len = max(max_seq_len, 2 * page_size)
    cfg, model, params = built or _build(arch, smoke)

    dense_pages = max_batch * (-(-max_seq_len // page_size))
    num_pages = max(4, int(dense_pages * pool_frac)) + 1
    serve = ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                        top_k=1, page_size=page_size, num_pages=num_pages)
    engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)

    rng = np.random.default_rng(seed)
    # mixed traffic: short chats + a few long-prompt / long-generation jobs
    reqs = []
    for i in range(n_requests):
        if i % 4 == 3:
            s = int(rng.integers(max_seq_len // 4, max_seq_len // 2))
            n = int(rng.integers(8, max(9, max_seq_len // 4)))
        else:
            s = int(rng.integers(2, max(3, max_seq_len // 8)))
            n = int(rng.integers(2, 16))
        n = max(1, min(n, max_seq_len - s))
        reqs.append(Request(id=i, prompt=rng.integers(
            0, cfg.vocab_size, size=s), max_new_tokens=n))

    # warmup: every batched-prefill width + multi-chunk prefill + fused
    # decode, so the timed region is not compile-dominated
    _warm(engine, cfg, serve, rng)

    t0 = time.perf_counter()
    ttft = {}
    events = []
    for ev in engine.generate_stream(reqs):
        if ev.index == 0:
            ttft[ev.request_id] = time.perf_counter() - t0
        events.append(ev)
    dt = time.perf_counter() - t0

    mgr = engine.last_cache
    total_new = sum(r.max_new_tokens for r in reqs)
    assert len(events) == total_new
    assert all(r.state == "FINISHED" for r in reqs)
    assert mgr.used_pages == 0, "pages leaked after drain"
    assert mgr.peak_used_pages <= num_pages - 1, "pool ceiling violated"

    tt = np.asarray(sorted(ttft.values()))
    stats = {
        "requests": n_requests,
        "generated_tokens": total_new,
        "prompt_tokens": int(sum(len(r.prompt) for r in reqs)),
        "wall_s": round(dt, 3),
        "tokens_per_s": round(total_new / dt, 1),
        # TTFT includes queueing: requests that wait for a slot pay it
        "ttft_mean_s": round(float(tt.mean()), 4),
        "ttft_p50_s": round(float(np.median(tt)), 4),
        "ttft_max_s": round(float(tt.max()), 4),
        "pool_pages": num_pages - 1,
        "dense_equiv_pages": dense_pages,
        "peak_pages": mgr.peak_used_pages,
        "peak_kv_frac_of_dense": round(
            mgr.peak_used_pages / dense_pages, 3),
        # the persistent core also counts warmup requests in
        # sched.finished; report this call's completions
        "finished": sum(1 for r in reqs if r.state == "FINISHED"),
    }
    return stats


def prefill_bench(arch: str = "gemma2-2b", prompt_len: int = 512,
                  page_size: int = 0, prefill_chunk: int = 0,
                  seed: int = 0, smoke: bool = True, built=None) -> dict:
    """Chunked vs scan prefill throughput (and TTFT) on one long prompt."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    cfg, model, params = built or _build(arch, smoke, small=True)
    max_seq_len = prompt_len + 2 * page_size
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, size=prompt_len)

    out = {"prompt_tokens": prompt_len, "d_model": cfg.d_model,
           "num_layers": cfg.num_layers}
    for mode in ("scan", "chunked"):
        serve = ServeConfig(max_batch=1, max_seq_len=max_seq_len, top_k=1,
                            page_size=page_size, prefill_mode=mode,
                            prefill_chunk=prefill_chunk)
        engine = ServeEngine(model=model, params=params, cfg=cfg,
                             serve=serve)
        times = []
        for rep in range(2):       # rep 0 is the compile warmup
            req = Request(id=rep, prompt=prompt, max_new_tokens=1)
            t0 = time.perf_counter()
            list(engine.generate_stream([req]))
            times.append(time.perf_counter() - t0)
        best = min(times[1:])
        # one request, one new token: the whole wall time is TTFT
        out[mode] = {
            "ttft_s": round(best, 4),
            "tokens_per_s": round(prompt_len / best, 1),
        }
        if mode == "chunked":
            out["prefill_chunk"] = serve.prefill_chunk_tokens
            out["kernel_launches"] = -(-prompt_len
                                       // serve.prefill_chunk_tokens)
    out["chunked_speedup_vs_scan"] = round(
        out["scan"]["ttft_s"] / out["chunked"]["ttft_s"], 2)
    return out


def oversubscribe(arch: str = "gemma2-2b", n_requests: int = 8,
                  max_batch: int = 6, page_size: int = 0,
                  max_seq_len: int = 64, pool_frac: float = 0.6,
                  preempt_policy: str = "swap", seed: int = 0,
                  smoke: bool = True, built=None) -> dict:
    """Offered load > pool capacity: reservation baseline vs optimistic
    admission + preemption on the identical workload and pool.  Needs
    enough decode slots that the *concurrent* demand of the slots can
    exceed the pool -- otherwise nothing ever pressures it."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    max_seq_len = max(max_seq_len, 4 * page_size)
    cfg, model, params = built or _build(arch, smoke)

    def make_requests():
        # fresh rng per run: both admission policies must see the
        # identical workload.  Every request runs to max_new_tokens (no
        # eos), so the offered worst-case demand is fully realised.
        rng = np.random.default_rng(seed)
        reqs = []
        for i in range(n_requests):
            if i % 3 == 0:
                s = int(rng.integers(max_seq_len // 4, max_seq_len // 2))
            else:
                s = int(rng.integers(4, max(5, max_seq_len // 8)))
            reqs.append(Request(id=i, prompt=rng.integers(
                0, cfg.vocab_size, size=s),
                max_new_tokens=max_seq_len - s))
        return reqs

    worst_pages = sum(-(-r.target_len // page_size)
                      for r in make_requests())
    num_pages = int(worst_pages * pool_frac) + 1

    out = {
        "requests": n_requests,
        "worst_case_pages": worst_pages,
        "pool_pages": num_pages - 1,
        "pool_frac_of_worst": round((num_pages - 1) / worst_pages, 3),
        "preempt_policy": preempt_policy,
    }
    for admission in ("reserved", "optimistic"):
        serve = ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                            top_k=1, page_size=page_size,
                            num_pages=num_pages, admission=admission,
                            preempt_policy=preempt_policy)
        engine = ServeEngine(model=model, params=params, cfg=cfg,
                             serve=serve)
        _warm(engine, cfg, serve, np.random.default_rng(seed + 1))
        reqs = make_requests()
        failures, error = 0, None
        t0 = time.perf_counter()
        try:
            events = list(engine.generate_stream(reqs))
        except Exception as e:         # count AND surface caller failures
            failures, events, error = 1, [], repr(e)
        dt = time.perf_counter() - t0
        mgr, pressure = engine.last_cache, engine.last_pressure
        total_new = sum(r.max_new_tokens for r in reqs)
        out[admission] = {
            "completed": sum(1 for r in reqs if r.state == "FINISHED"),
            "caller_failures": failures,
            "error": error,
            "generated_tokens": len(events),
            "wall_s": round(dt, 3),
            "tokens_per_s": round(total_new / dt, 1),
            "preemptions": pressure.stats["preemptions"],
            "swaps": pressure.stats["swaps"],
            "recomputes": pressure.stats["recomputes"],
            "swap_bytes_out": pressure.stats["swap_bytes_out"],
            "swap_bytes_in": pressure.stats["swap_bytes_in"],
            "host_pool_peak_pages": pressure.host_pool.peak_pages,
            "peak_pages": mgr.peak_used_pages,
            "peak_utilization": round(mgr.peak_utilization, 3),
            "pages_leaked": mgr.used_pages,
        }
    return out


def prefix_sharing(arch: str = "gemma2-2b", n_requests: int = 6,
                   system_len: int = 96, unique_len: int = 12,
                   max_batch: int = 3, page_size: int = 0,
                   max_new: int = 4, seed: int = 0, smoke: bool = True,
                   built=None) -> dict:
    """Shared-system-prompt workload, cold (no prefix cache) vs warm
    (radix index seeded by a prior run on the same engine)."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    system_len = max(system_len, 2 * page_size)
    cfg, model, params = built or _build(arch, smoke)
    max_seq_len = system_len + unique_len + max_new + page_size
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=system_len)

    def make_requests(run_seed):
        r = np.random.default_rng(run_seed)
        return [Request(id=i, prompt=np.concatenate(
            [sys_prompt, r.integers(0, cfg.vocab_size, size=unique_len)]),
            max_new_tokens=max_new) for i in range(n_requests)]

    def serve_cfg(prefix):
        return ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                           top_k=1, page_size=page_size,
                           prefix_cache=prefix)

    def timed_run(engine, reqs):
        failures, error, events, ttft = 0, None, [], {}
        t0 = time.perf_counter()
        try:
            for ev in engine.generate_stream(reqs):
                if ev.index == 0:
                    ttft[ev.request_id] = time.perf_counter() - t0
                events.append(ev)
        except Exception as e:
            failures, error = 1, repr(e)
        dt = time.perf_counter() - t0
        computed = sum(len(r.prompt) - r.matched_len for r in reqs)
        mgr = engine.last_cache
        return {
            "completed": sum(1 for r in reqs if r.state == "FINISHED"),
            "caller_failures": failures,
            "error": error,
            "wall_s": round(dt, 3),
            "ttft_mean_s": round(float(np.mean(list(ttft.values()))), 4)
            if ttft else None,
            "prompt_tokens": int(sum(len(r.prompt) for r in reqs)),
            "prefill_tokens_computed": int(computed),
            "matched_tokens": int(sum(r.matched_len for r in reqs)),
            "pages_resident": mgr.used_pages,
        }, [r.generated for r in reqs]

    shared_aligned = (system_len // page_size) * page_size
    out = {
        "requests": n_requests,
        "system_prompt_tokens": system_len,
        "unique_tokens": unique_len,
        "shared_aligned_tokens": shared_aligned,
        # the fraction of prefill work the cache should at least save
        "shared_prefix_fraction": round(
            shared_aligned / (system_len + unique_len), 3),
    }

    # cold: no prefix cache, every prompt prefills from token 0
    cold = ServeEngine(model=model, params=params, cfg=cfg,
                       serve=serve_cfg(False))
    _warm(cold, cfg, cold.serve, np.random.default_rng(seed + 1))
    out["cold"], cold_tokens = timed_run(cold, make_requests(seed + 2))

    # warm: same engine config with the radix index, seeded by one run
    eng = ServeEngine(model=model, params=params, cfg=cfg,
                      serve=serve_cfg(True))
    _warm(eng, cfg, eng.serve, np.random.default_rng(seed + 1))
    out["seed_run"], _ = timed_run(eng, make_requests(seed + 3))
    out["warm"], warm_tokens = timed_run(eng, make_requests(seed + 2))
    out["cached_pages"] = eng.last_prefix.cached_pages
    out["tokens_bit_identical"] = bool(warm_tokens == cold_tokens)
    return out


def open_loop(arch: str = "gemma2-2b", n_requests: int = 10,
              max_batch: int = 3, page_size: int = 0,
              max_seq_len: int = 96, mean_gap_steps: float = 2.0,
              seed: int = 0, smoke: bool = True, built=None) -> dict:
    """Open-loop serving through ``EngineCore.step()``: a deterministic
    seeded Poisson process schedules arrivals *by engine step* (each
    inter-arrival gap ~ Exp(mean_gap_steps)), requests carry mixed
    SamplingParams (greedy chats and seeded sampling jobs), and the
    driver measures what a frontend would: TTFT (arrival -> first
    token) and TPOT (mean gap between a request's tokens)."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    max_seq_len = max(max_seq_len, 4 * page_size)
    cfg, model, params = built or _build(arch, smoke)

    serve = ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                        page_size=page_size,
                        num_pages=max_batch * 3 + 1)   # undersized: churn
    core = EngineCore(model, params, cfg, serve)

    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(
        rng.exponential(scale=mean_gap_steps, size=n_requests))).astype(int)
    specs = []
    for i in range(n_requests):
        s = int(rng.integers(3, max_seq_len // 3))
        n = int(rng.integers(4, max(5, (max_seq_len - s) // 2)))
        if i % 3 == 2:                 # every 3rd request samples
            sp = SamplingParams(temperature=0.8, top_k=8, seed=seed + i,
                                max_new_tokens=n)
        else:
            sp = SamplingParams(max_new_tokens=n)
        specs.append((rng.integers(0, cfg.vocab_size, size=s), sp))

    # warmup: compile the decode step and every chunk-launch width the
    # schedule may hit, then reset the serving state (jit caches stay)
    widths, w = [], 1
    while w < max_batch:
        widths.append(w)
        w *= 2
    widths.append(max_batch)
    wid = 0
    for w in widths:
        for i in range(w):
            wid -= 1
            core.add_request(rng.integers(0, cfg.vocab_size, size=3 + i),
                             SamplingParams(max_new_tokens=2),
                             request_id=wid)
        while core.has_work:
            core.step()
    core.reset()
    core.reset_metrics_window()   # drop warmup from the metrics window

    t_arrive, t_first, t_last, n_toks = {}, {}, {}, {}
    next_req = 0
    step_idx = 0
    t0 = time.perf_counter()
    while next_req < n_requests or core.has_work:
        while next_req < n_requests and arrivals[next_req] <= step_idx:
            prompt, sp = specs[next_req]
            rid = core.add_request(prompt, sp, request_id=next_req)
            t_arrive[rid] = time.perf_counter()
            next_req += 1
        for ev in core.step():
            now = time.perf_counter()
            t_first.setdefault(ev.request_id, now)
            t_last[ev.request_id] = now
            n_toks[ev.request_id] = n_toks.get(ev.request_id, 0) + 1
        step_idx += 1
    wall = time.perf_counter() - t0

    assert len(t_first) == n_requests, "some request never produced"
    assert core.mgr.used_pages == 0, "pages leaked after drain"
    stats = core.stats()
    assert stats["finished"] == n_requests

    ttft = np.asarray([t_first[i] - t_arrive[i] for i in range(n_requests)])
    tpot = np.asarray([(t_last[i] - t_first[i]) / (n_toks[i] - 1)
                       for i in range(n_requests) if n_toks[i] > 1])
    total_toks = sum(n_toks.values())
    # engine-native latencies from the lifecycle tracer, stamped on the
    # engine's own clock at submit/first-token/last-token (warmup
    # requests were cleared by reset_metrics_window).  Stamps are taken
    # *inside* the step, so work that lands later in the same step (a
    # cold sampling compile, another request's prefill) never inflates
    # them -- the step-granular driver above can only observe tokens
    # after step() returns and lumps that in.  tests/test_metrics.py
    # proves exact engine-vs-bench agreement under a manual clock.
    done = [r for r in core.tracer.completed if r["reason"] == "finished"]
    nat_ttft = np.asarray([r["first_token_t"] - r["submit_t"]
                           for r in done])
    nat_tpot = np.asarray([
        (r["last_token_t"] - r["first_token_t"]) / (r["n_tokens"] - 1)
        for r in done if r["n_tokens"] > 1])
    return {
        "requests": n_requests,
        "mean_gap_steps": mean_gap_steps,
        "engine_steps": stats["steps"],
        "generated_tokens": total_toks,
        "sampled_requests": sum(1 for _, sp in specs if not sp.greedy),
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total_toks / wall, 1),
        "ttft_p50_s": round(float(np.percentile(ttft, 50)), 4),
        "ttft_p99_s": round(float(np.percentile(ttft, 99)), 4),
        "tpot_p50_s": round(float(np.percentile(tpot, 50)), 4),
        "tpot_p99_s": round(float(np.percentile(tpot, 99)), 4),
        "engine_ttft_p50_s": round(float(np.percentile(nat_ttft, 50)), 4),
        "engine_ttft_p99_s": round(float(np.percentile(nat_ttft, 99)), 4),
        "engine_tpot_p50_s": round(float(np.percentile(nat_tpot, 50)), 4),
        "engine_open_spans_after_drain": core.tracer.open_span_count(),
        "preemptions": stats["pressure"]["preemptions"],
        "peak_utilization": round(stats["peak_utilization"], 3),
    }


def degradation(arch: str = "gemma2-2b", n_requests: int = 14,
                max_batch: int = 3, page_size: int = 0,
                max_seq_len: int = 96, mean_gap_steps: float = 0.5,
                deadline_ms: float = 1000.0, max_waiting: int = 2,
                seed: int = 0, smoke: bool = True, built=None) -> dict:
    """Graceful degradation under over-offered load: the same seeded
    Poisson arrival schedule (arrivals ~2x faster than the engine
    drains) driven through ``EngineCore.step()`` twice -- once
    *unbounded* (every request queues forever, no deadline) and once
    *bounded* (``max_waiting`` + ``queue_policy="shed_oldest"`` +
    per-request ``deadline_ms``).  The unbounded engine completes
    everything at the cost of unbounded queueing latency; the bounded
    engine sheds excess load with structured errors and keeps the
    survivors' TTFT/TPOT tail flat.  The CI artifact check gates on the
    survivors' p99 not regressing past the unbounded baseline."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    max_seq_len = max(max_seq_len, 4 * page_size)
    cfg, model, params = built or _build(arch, smoke)

    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(
        rng.exponential(scale=mean_gap_steps, size=n_requests))).astype(int)
    specs = [(rng.integers(0, cfg.vocab_size,
                           size=int(rng.integers(4, max_seq_len // 3))),
              int(rng.integers(6, 14))) for _ in range(n_requests)]

    def drive(bounded: bool) -> dict:
        serve = ServeConfig(
            max_batch=max_batch, max_seq_len=max_seq_len,
            page_size=page_size, num_pages=max_batch * 3 + 1,
            max_waiting=max_waiting if bounded else 0,
            queue_policy="shed_oldest")
        core = EngineCore(model, params, cfg, serve)
        # warmup: decode + every chunk-launch width, then reset state
        wid = 0
        for w in (1, 2, max_batch):
            for i in range(w):
                wid -= 1
                core.add_request(
                    rng.integers(0, cfg.vocab_size, size=3 + i),
                    SamplingParams(max_new_tokens=2), request_id=wid)
            while core.has_work:
                core.step()
        core.reset()
        # fresh metrics window: ``step_s_high_water`` below must be the
        # timed workload's slowest step, not the warmup's compile steps
        # (which dwarf every steady-state step and used to mask it)
        core.reset_metrics_window()

        t_arrive, t_first, t_last, n_toks = {}, {}, {}, {}
        next_req, step_idx, waiting_hw = 0, 0, 0
        t0 = time.perf_counter()
        while next_req < n_requests or core.has_work:
            while next_req < n_requests and arrivals[next_req] <= step_idx:
                prompt, n = specs[next_req]
                sp = SamplingParams(
                    max_new_tokens=n,
                    deadline_ms=deadline_ms if bounded else None)
                core.add_request(prompt, sp, request_id=next_req)
                t_arrive[next_req] = time.perf_counter()
                next_req += 1
            for ev in core.step():
                if ev.kind != "token":
                    continue
                now = time.perf_counter()
                t_first.setdefault(ev.request_id, now)
                t_last[ev.request_id] = now
                n_toks[ev.request_id] = n_toks.get(ev.request_id, 0) + 1
            waiting_hw = max(waiting_hw, len(core.sched.waiting))
            step_idx += 1
        wall = time.perf_counter() - t0
        assert core.mgr.used_pages == 0, "pages leaked after drain"

        stats = core.stats()
        health = stats["health"]
        done = sorted(r.id for r in core.sched.finished if r.id >= 0)
        ttft = np.asarray([t_first[i] - t_arrive[i] for i in done])
        tpot = np.asarray([(t_last[i] - t_first[i]) / (n_toks[i] - 1)
                           for i in done if n_toks.get(i, 0) > 1])
        total = sum(n_toks.values())
        out = {
            "completed": len(done),
            "shed": health["shed"],
            "timed_out": health["timed_out"],
            "failed": health["failed"],
            "waiting_high_water": waiting_hw,
            "engine_steps": stats["steps"],
            "generated_tokens": total,
            "wall_s": round(wall, 3),
            "survivor_ttft_p50_s": round(
                float(np.percentile(ttft, 50)), 4),
            "survivor_ttft_p99_s": round(
                float(np.percentile(ttft, 99)), 4),
            "survivor_tpot_p50_s": round(
                float(np.percentile(tpot, 50)), 4),
            "survivor_tpot_p99_s": round(
                float(np.percentile(tpot, 99)), 4),
            "step_s_high_water": round(health["step_s_high_water"], 4),
        }
        if bounded:
            out["shed_rate"] = round(
                (health["shed"] + health["timed_out"]) / n_requests, 3)
        return out

    report = {
        "requests": n_requests,
        "mean_gap_steps": mean_gap_steps,
        "deadline_ms": deadline_ms,
        "max_waiting": max_waiting,
        "queue_policy": "shed_oldest",
        "unbounded": drive(False),
        "bounded": drive(True),
    }
    b, u = report["bounded"], report["unbounded"]
    assert u["completed"] == n_requests, "unbounded baseline lost requests"
    assert b["completed"] + b["shed"] + b["timed_out"] == n_requests
    report["survivor_ttft_p99_ratio"] = round(
        b["survivor_ttft_p99_s"] / u["survivor_ttft_p99_s"], 3)
    report["survivor_tpot_p99_ratio"] = round(
        b["survivor_tpot_p99_s"] / u["survivor_tpot_p99_s"], 3)
    return report


def observability(arch: str = "gemma2-2b", n_requests: int = 10,
                  max_batch: int = 3, page_size: int = 0,
                  max_seq_len: int = 96, mean_gap_steps: float = 2.0,
                  repeats: int = 2, seed: int = 0, smoke: bool = True,
                  built=None, trace_out: str = "") -> dict:
    """Telemetry overhead: the open-loop workload through
    ``EngineCore.step()`` with the full telemetry stack on
    (``metrics=True``: registry, lifecycle tracer, flight recorder,
    per-step phase histograms) vs completely off, best-of-``repeats``
    ms/step each way.  The instrumentation is all host-side Python
    between launches, so the ratio must stay ~1.0; CI gates it at 1.05.
    The metrics-on run also dumps the flight recorder's Chrome trace."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    max_seq_len = max(max_seq_len, 4 * page_size)
    cfg, model, params = built or _build(arch, smoke)

    rng0 = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng0.exponential(
        scale=mean_gap_steps, size=n_requests))).astype(int)
    specs = [(rng0.integers(0, cfg.vocab_size,
                            size=int(rng0.integers(3, max_seq_len // 3))),
              int(rng0.integers(4, 10))) for _ in range(n_requests)]

    def drive(metrics_on: bool):
        serve = ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                            page_size=page_size,
                            num_pages=max_batch * 3 + 1,
                            metrics=metrics_on)
        core = EngineCore(model, params, cfg, serve)
        rng = np.random.default_rng(seed + 1)
        wid = 0
        for w in (1, 2, max_batch):       # compile every launch width
            for i in range(w):
                wid -= 1
                core.add_request(rng.integers(0, cfg.vocab_size, size=3 + i),
                                 SamplingParams(max_new_tokens=2),
                                 request_id=wid)
            while core.has_work:
                core.step()
        core.reset()
        if metrics_on:
            core.reset_metrics_window()
        best = None
        for rep in range(repeats):        # identical arrival schedule
            next_req, step_idx, steps = 0, 0, 0
            t0 = time.perf_counter()
            while next_req < n_requests or core.has_work:
                while (next_req < n_requests
                       and arrivals[next_req] <= step_idx):
                    prompt, n = specs[next_req]
                    core.add_request(prompt,
                                     SamplingParams(max_new_tokens=n),
                                     request_id=1000 * rep + next_req)
                    next_req += 1
                core.step()
                steps += 1
                step_idx += 1
            dt = time.perf_counter() - t0
            assert core.mgr.used_pages == 0, "pages leaked after drain"
            ms = 1e3 * dt / steps
            best = ms if best is None else min(best, ms)
        return best, core

    # off first, on second: any in-process cache the second run could
    # inherit biases *against* finding overhead in the on run -- i.e.
    # keeps the CI gate conservative and stable
    off_ms, _ = drive(False)
    on_ms, core = drive(True)

    out = {
        "requests": n_requests,
        "repeats": repeats,
        "metrics_on_ms_per_step": round(on_ms, 2),
        "metrics_off_ms_per_step": round(off_ms, 2),
        "overhead_ratio": round(on_ms / off_ms, 3),
        "open_spans_after_drain": core.tracer.open_span_count(),
        "flight_records": len(core.flight.records),
    }
    if trace_out:
        trace = core.chrome_trace()
        with open(trace_out, "w") as f:
            json.dump(trace, f)
            f.write("\n")
        out["trace_events"] = len(trace["traceEvents"])
        out["trace_file"] = os.path.basename(trace_out)
    return out


def speculation(arch: str = "gemma2-2b", n_requests: int = 6,
                max_batch: int = 3, page_size: int = 0,
                spec_tokens: int = 4, gen_tokens: int = 24,
                repeats: int = 3, seed: int = 0, smoke: bool = True,
                built=None) -> dict:
    """Speculative decoding on a lookup-friendly workload: repetitive
    (tiled-motif) greedy prompts through the engine with prompt-lookup
    drafting on vs off.  Reports the accept rate, per-token time both
    ways (same tokens -- ``tokens_match`` asserts the greedy
    bit-identity contract), and ``off_step_time_ratio``: two *identical*
    spec-off engines timed interleaved, best-of-``repeats`` each -- the
    off path shares no code with speculation beyond a per-step ``is
    None`` branch, so CI gates the ratio at 1.02 (measurement noise)."""
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    cfg, model, params = built or _build(arch, smoke)

    rng = np.random.default_rng(seed)
    prompts = []
    for i in range(n_requests):
        motif = rng.integers(1, cfg.vocab_size, size=5 + i % 3).tolist()
        n = 24 + 4 * (i % 4)
        prompts.append(np.array((motif * (n // len(motif) + 1))[:n],
                                np.int32))
    max_seq_len = max(p.size for p in prompts) + gen_tokens + page_size
    base = ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                       page_size=page_size)

    def drive(core, rep):
        for i, p in enumerate(prompts):
            core.add_request(p, SamplingParams(max_new_tokens=gen_tokens),
                             request_id=1000 * rep + i)
        toks = {i: [] for i in range(n_requests)}
        steps0, t0 = core.steps, time.perf_counter()
        while core.has_work:
            for ev in core.step():
                if ev.kind == "token":
                    toks[ev.request_id % 1000].append(ev.token)
        dt = time.perf_counter() - t0
        assert core.mgr.used_pages == 0, "pages leaked after drain"
        return toks, dt, core.steps - steps0

    def timed(core):
        """Warm (compile), then best-of-``repeats`` full drains.  The
        greedy reps are identical, so per-rep spec counters are just
        the timed totals divided by ``repeats``."""
        drive(core, 0)
        core.reset()
        core.reset_metrics_window()
        launch0, best = core.spec_launches, None
        for rep in range(1, repeats + 1):
            toks, dt, steps = drive(core, rep)
            core.reset()
            if best is None or dt < best[1]:
                best = (toks, dt, steps)
        return best + ((core.spec_launches - launch0) // repeats,)

    core_off = EngineCore(model, params, cfg, base)
    core_on = EngineCore(model, params, cfg, dataclasses.replace(
        base, spec_mode="lookup", spec_tokens=spec_tokens))
    off_toks, off_dt, off_steps, _ = timed(core_off)
    on_toks, on_dt, on_steps, on_launches = timed(core_on)
    sp = core_on.stats()["spec"]         # windows cover the timed reps

    # off-mode overhead: two identical spec-off engines, interleaved
    # best-of-``repeats`` -- any ratio above noise would mean the off
    # path is paying for a feature it never runs
    core_a = EngineCore(model, params, cfg, base)
    core_b = EngineCore(model, params, cfg, base)
    for c in (core_a, core_b):
        drive(c, 0)
        c.reset()
    best_a = best_b = None
    for rep in range(1, repeats + 1):
        _, dt_a, _ = drive(core_a, rep)
        core_a.reset()
        _, dt_b, _ = drive(core_b, rep)
        core_b.reset()
        best_a = dt_a if best_a is None else min(best_a, dt_a)
        best_b = dt_b if best_b is None else min(best_b, dt_b)

    n_gen = sum(len(t) for t in off_toks.values())
    return {
        "requests": n_requests,
        "spec_tokens": spec_tokens,
        "generated_tokens": n_gen,
        "tokens_match": bool(on_toks == off_toks),
        "drafted": sp["drafted"] // repeats,
        "accepted": sp["accepted"] // repeats,
        "accept_rate": round(sp["accept_rate"], 3),
        "off": {
            "ms_per_step": round(1e3 * off_dt / off_steps, 2),
            "tpot_ms": round(1e3 * off_dt / n_gen, 2),
            "engine_steps": off_steps,
        },
        "on": {
            "ms_per_step": round(1e3 * on_dt / on_steps, 2),
            "tpot_ms": round(1e3 * on_dt / n_gen, 2),
            "engine_steps": on_steps,
            "verify_launches": on_launches,
        },
        "tpot_speedup": round(off_dt / on_dt, 3),
        "off_step_time_ratio": round(best_b / best_a, 3),
    }


def _distributed_child(arch: str, n_requests: int, seed: int,
                       smoke: bool = True) -> None:
    """Runs INSIDE the forced-multi-device child process: tp=1 oracle,
    tp=2 / tp=4 tiled and tp=4 single-AllReduce runs of one workload;
    prints the section JSON on the last stdout line."""
    cfg, model, params = _build(arch, smoke)
    rng = np.random.default_rng(seed)
    prompts = []
    for i in range(n_requests):
        s = int(rng.integers(4, 40))
        prompts.append(rng.integers(0, cfg.vocab_size, size=s))
    max_new = 16

    def run_tp(tp, collectives="tiled"):
        serve = ServeConfig(max_batch=4, max_seq_len=96, page_size=16,
                            prefill_chunk=16, tp=tp,
                            tp_collectives=collectives)
        core = EngineCore(model=model, params=params, cfg=cfg, serve=serve)

        def drain(offset):
            toks = {}
            while core.has_work:
                for ev in core.step():
                    toks.setdefault(ev.request_id - offset,
                                    []).append(ev.token)
            return toks

        # pass 0 compiles (prefill widths + fused decode); pass 1 is the
        # timed, steady-state measurement on the same jit caches
        for i, p in enumerate(prompts):
            core.add_request(p, SamplingParams(max_new_tokens=max_new),
                             request_id=i)
        toks = drain(0)
        for i, p in enumerate(prompts):
            core.add_request(p, SamplingParams(max_new_tokens=max_new),
                             request_id=1000 + i)
        steps0 = core.stats()["steps"]
        t0 = time.perf_counter()
        timed = drain(1000)
        dt = time.perf_counter() - t0
        steps = core.stats()["steps"] - steps0
        assert timed == toks, "engine output changed between passes"
        total = sum(len(v) for v in timed.values())
        return toks, {
            "wall_s": round(dt, 3),
            "engine_steps": steps,
            "ms_per_step": round(1e3 * dt / steps, 2),
            "tokens_per_s": round(total / dt, 1),
        }

    base, t1 = run_tp(1)
    report = {
        "devices": jax.device_count(),
        "requests": n_requests,
        "generated_tokens": n_requests * max_new,
        "tokens_match": {},
        "tp1": t1,
    }
    for tp, coll in ((2, "tiled"), (4, "tiled"), (4, "single")):
        toks, timing = run_tp(tp, coll)
        report["tokens_match"][f"tp{tp}-{coll}"] = bool(toks == base)
        report[f"tp{tp}-{coll}"] = timing
    report["tp4_tiled_vs_single_step_speedup"] = round(
        report["tp4-single"]["ms_per_step"]
        / report["tp4-tiled"]["ms_per_step"], 3)
    print(json.dumps(report))


def distributed(arch: str = "gemma2-2b", n_requests: int = 6,
                devices: int = 4, seed: int = 0,
                smoke: bool = True) -> dict:
    """Tensor-parallel serving section: spawns a child process with
    ``devices`` forced fake CPU devices (the main process keeps its
    single-device view) and collects its report."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(REPO_ROOT, "src"), REPO_ROOT]))
    code = (f"from benchmarks.serving_bench import _distributed_child; "
            f"_distributed_child({arch!r}, {n_requests}, {seed}, {smoke})")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=REPO_ROOT, capture_output=True, text=True,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(
            f"distributed bench child failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=0,
                    help="0 = auto (128 on TPU, 16 on CPU smoke)")
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--pool-frac", type=float, default=0.6,
                    help="pool size as a fraction of the dense cache")
    ap.add_argument("--prefill-len", type=int, default=512,
                    help="prompt length for the prefill section")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunk size (0 = auto: 4 pages)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) model config")
    ap.add_argument("--skip-prefill", action="store_true",
                    help="skip the scan-vs-chunked prefill section")
    ap.add_argument("--skip-oversub", action="store_true",
                    help="skip the over-subscription section")
    ap.add_argument("--oversub-requests", type=int, default=8)
    ap.add_argument("--skip-prefix", action="store_true",
                    help="skip the prefix-sharing section")
    ap.add_argument("--prefix-requests", type=int, default=6)
    ap.add_argument("--skip-open-loop", action="store_true",
                    help="skip the open-loop EngineCore section")
    ap.add_argument("--open-loop-requests", type=int, default=10)
    ap.add_argument("--skip-degradation", action="store_true",
                    help="skip the load-shedding degradation section")
    ap.add_argument("--degradation-requests", type=int, default=14)
    ap.add_argument("--deadline-ms", type=float, default=1000.0,
                    help="per-request deadline in the bounded run")
    ap.add_argument("--max-waiting", type=int, default=2,
                    help="waiting-queue bound in the bounded run")
    ap.add_argument("--skip-observability", action="store_true",
                    help="skip the telemetry-overhead section")
    ap.add_argument("--observability-requests", type=int, default=10)
    ap.add_argument("--trace-out", default=os.path.join(
        REPO_ROOT, "BENCH_serving_trace.json"),
        help="flight-recorder Chrome trace artifact path ('' = skip)")
    ap.add_argument("--skip-speculation", action="store_true",
                    help="skip the speculative-decoding section")
    ap.add_argument("--speculation-requests", type=int, default=6)
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="max draft tokens per request per step")
    ap.add_argument("--skip-distributed", action="store_true",
                    help="skip the tensor-parallel serving section")
    ap.add_argument("--distributed-requests", type=int, default=6)
    ap.add_argument("--tp-devices", type=int, default=4,
                    help="forced fake CPU devices for the TP child")
    ap.add_argument("--mean-gap-steps", type=float, default=2.0,
                    help="mean Poisson inter-arrival gap (engine steps)")
    ap.add_argument("--system-len", type=int, default=96,
                    help="shared system-prompt length (prefix section)")
    ap.add_argument("--preempt-policy", default="swap",
                    choices=["auto", "swap", "recompute"])
    ap.add_argument("--json-out", default=os.path.join(
        REPO_ROOT, "BENCH_serving.json"))
    args = ap.parse_args()

    report = {
        "meta": {
            "arch": args.arch,
            "smoke": not args.full,
            "backend": jax.default_backend(),
            "paged_impl": ("paged" if jax.default_backend() == "tpu"
                           else "paged_reference"),
        },
        # tiny unit-test config: exercises slot/page churn
        "mixed_traffic": run(
            arch=args.arch, n_requests=args.requests,
            max_batch=args.max_batch, page_size=args.page_size,
            max_seq_len=args.max_seq_len, pool_frac=args.pool_frac,
            seed=args.seed, smoke=not args.full),
    }
    if not args.skip_prefill:
        # 'small' config: wide enough that prefill batching shows
        report["prefill"] = prefill_bench(
            arch=args.arch, prompt_len=args.prefill_len,
            page_size=args.page_size, prefill_chunk=args.prefill_chunk,
            seed=args.seed, smoke=not args.full)
    if not args.skip_oversub:
        # pool at pool_frac of realised worst-case demand: the pressure
        # subsystem (preempt + swap/recompute) absorbs the difference
        report["oversubscription"] = oversubscribe(
            arch=args.arch, n_requests=args.oversub_requests,
            page_size=args.page_size, pool_frac=args.pool_frac,
            preempt_policy=args.preempt_policy, seed=args.seed,
            smoke=not args.full)
    if not args.skip_prefix:
        # shared system prompt, cold vs warm: the radix prefix cache
        # must cut warm prefill work by >= the shared-prefix fraction
        report["prefix_sharing"] = prefix_sharing(
            arch=args.arch, n_requests=args.prefix_requests,
            system_len=args.system_len, page_size=args.page_size,
            seed=args.seed, smoke=not args.full)
    if not args.skip_open_loop:
        # requests arriving while the engine runs (EngineCore.step
        # driven directly): frontend-visible TTFT/TPOT percentiles
        report["open_loop"] = open_loop(
            arch=args.arch, n_requests=args.open_loop_requests,
            page_size=args.page_size,
            mean_gap_steps=args.mean_gap_steps, seed=args.seed,
            smoke=not args.full)
    if not args.skip_degradation:
        # over-offered load, unbounded vs deadline+shed bounded engine:
        # the survivors' latency tail must not regress under shedding
        report["degradation"] = degradation(
            arch=args.arch, n_requests=args.degradation_requests,
            page_size=args.page_size, deadline_ms=args.deadline_ms,
            max_waiting=args.max_waiting, seed=args.seed,
            smoke=not args.full)
    if not args.skip_observability:
        # metrics-on vs metrics-off step time on the open-loop workload:
        # telemetry must be free (host-side, between launches)
        report["observability"] = observability(
            arch=args.arch, n_requests=args.observability_requests,
            page_size=args.page_size,
            mean_gap_steps=args.mean_gap_steps, seed=args.seed,
            smoke=not args.full, trace_out=args.trace_out)
    if not args.skip_speculation:
        # prompt-lookup speculation on a repetitive greedy workload:
        # same tokens in fewer, fatter steps; off mode must stay free
        report["speculation"] = speculation(
            arch=args.arch, n_requests=args.speculation_requests,
            page_size=args.page_size, spec_tokens=args.spec_tokens,
            seed=args.seed, smoke=not args.full)
    if not args.skip_distributed:
        # tensor-parallel engine on a forced multi-device CPU mesh:
        # bit-identity vs tp=1 and tiled- vs single-AllReduce step time
        report["distributed"] = distributed(
            arch=args.arch, n_requests=args.distributed_requests,
            devices=args.tp_devices, seed=args.seed, smoke=not args.full)

    def flat(prefix, d):
        for k, v in d.items():
            if isinstance(v, dict):
                flat(f"{prefix}{k}.", v)
            else:
                print(f"{prefix}{k},{v}", flush=True)
    flat("", report)
    with open(args.json_out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
