"""Mixed-length-traffic serving benchmark: paged KV + continuous batching.

Streams a queue of requests with randomised prompt/generation lengths
through ``ServeEngine.generate_stream`` and reports:

  * decode throughput (tokens/s) and per-token latency,
  * slot occupancy (how full the decode batch stayed -- the quantity
    continuous batching exists to maximise),
  * page-pool pressure: peak pages in use vs the configured pool, proving
    admission control keeps KV memory bounded while slots/pages recycle.

The pool is deliberately sized *below* ``max_batch * max_seq_len`` (the
dense cache's footprint): the scheduler trades a longer queue for a hard
memory ceiling, which a dense static-batch engine cannot do at all.

    PYTHONPATH=src python -m benchmarks.serving_bench \
        [--arch gemma2-2b] [--requests 12] [--max-batch 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import ParallelConfig, ServeConfig, get_model_config, \
    reduce_for_smoke
from repro.models import build_model
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request


def run(arch: str = "gemma2-2b", n_requests: int = 12, max_batch: int = 4,
        page_size: int = 0, max_seq_len: int = 128, pool_frac: float = 0.6,
        seed: int = 0, smoke: bool = True) -> dict:
    # 0 = auto: the TPU kernel needs lane-width (128) pages; CPU smoke
    # runs use small pages so slot/page churn actually happens
    page_size = page_size or (
        128 if jax.default_backend() == "tpu" else 16)
    max_seq_len = max(max_seq_len, 2 * page_size)
    cfg = get_model_config(arch)
    if smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))

    dense_pages = max_batch * (-(-max_seq_len // page_size))
    num_pages = max(4, int(dense_pages * pool_frac)) + 1
    serve = ServeConfig(max_batch=max_batch, max_seq_len=max_seq_len,
                        top_k=1, page_size=page_size, num_pages=num_pages)
    engine = ServeEngine(model=model, params=params, cfg=cfg, serve=serve)

    rng = np.random.default_rng(seed)
    # mixed traffic: short chats + a few long-prompt / long-generation jobs
    reqs = []
    for i in range(n_requests):
        if i % 4 == 3:
            s = int(rng.integers(max_seq_len // 4, max_seq_len // 2))
            n = int(rng.integers(8, max(9, max_seq_len // 4)))
        else:
            s = int(rng.integers(2, max(3, max_seq_len // 8)))
            n = int(rng.integers(2, 16))
        n = max(1, min(n, max_seq_len - s))
        reqs.append(Request(id=i, prompt=rng.integers(
            0, cfg.vocab_size, size=s), max_new_tokens=n))

    # warmup: the jitted prefill retraces per distinct prompt length, so
    # trace one request of every length in the workload (plus the shared
    # decode step) -- otherwise the timed region is compile-dominated
    warm_lens = sorted({len(r.prompt) for r in reqs})
    warms = [Request(id=-1 - i, prompt=rng.integers(
                 0, cfg.vocab_size, size=s), max_new_tokens=2)
             for i, s in enumerate(warm_lens)]
    list(engine.generate_stream(warms))

    t0 = time.perf_counter()
    events = list(engine.generate_stream(reqs))
    dt = time.perf_counter() - t0

    mgr, sched = engine.last_cache, engine.last_scheduler
    total_new = sum(r.max_new_tokens for r in reqs)
    assert len(events) == total_new
    assert all(r.state == "FINISHED" for r in reqs)
    assert mgr.used_pages == 0, "pages leaked after drain"
    assert mgr.peak_used_pages <= num_pages - 1, "pool ceiling violated"

    stats = {
        "requests": n_requests,
        "generated_tokens": total_new,
        "prompt_tokens": int(sum(len(r.prompt) for r in reqs)),
        "wall_s": round(dt, 3),
        "tokens_per_s": round(total_new / dt, 1),
        "pool_pages": num_pages - 1,
        "dense_equiv_pages": dense_pages,
        "peak_pages": mgr.peak_used_pages,
        "peak_kv_frac_of_dense": round(
            mgr.peak_used_pages / dense_pages, 3),
        "finished": len(sched.finished),
    }
    return stats


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=0,
                    help="0 = auto (128 on TPU, 16 on CPU smoke)")
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--pool-frac", type=float, default=0.6,
                    help="pool size as a fraction of the dense cache")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) model config")
    args = ap.parse_args()
    stats = run(arch=args.arch, n_requests=args.requests,
                max_batch=args.max_batch, page_size=args.page_size,
                max_seq_len=args.max_seq_len, pool_frac=args.pool_frac,
                seed=args.seed, smoke=not args.full)
    for k, v in stats.items():
        print(f"{k},{v}", flush=True)


if __name__ == "__main__":
    main()
