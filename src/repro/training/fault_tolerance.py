"""Fault tolerance + elasticity for 1000+-node runs.

Components (cluster interactions simulated; decision logic real & tested):

  HeartbeatMonitor   -- tracks per-host liveness; flags missing hosts.
  StragglerDetector  -- per-step host timing; robust z-score quarantine.
  elastic_plan       -- shrink the data axis to the surviving host count,
                        keeping model/pod axes intact (weights survive,
                        only the batch sharding changes), and reshard via
                        CheckpointManager.restore(shardings=...).
  CadenceController  -- adapts checkpoint frequency to observed MTBF so
                        expected lost work stays under a budget.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config import ParallelConfig


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[str], timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last_seen: Dict[str, float] = {
            h: time.time() for h in hosts}  # repro-lint: disable=raw-wall-clock (heartbeat)

    def beat(self, host: str, t: Optional[float] = None):
        self.last_seen[host] = time.time() if t is None else t  # repro-lint: disable=raw-wall-clock

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = time.time() if now is None else now  # repro-lint: disable=raw-wall-clock (heartbeat)
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout]

    def alive_hosts(self, now: Optional[float] = None) -> List[str]:
        dead = set(self.dead_hosts(now))
        return [h for h in self.last_seen if h not in dead]


class StragglerDetector:
    """Flags hosts whose step time is a robust outlier (median + k*MAD)."""

    def __init__(self, k: float = 4.0, window: int = 20):
        self.k = k
        self.window = window
        self.history: Dict[str, List[float]] = {}

    def record(self, host: str, step_time_s: float):
        self.history.setdefault(host, []).append(step_time_s)
        self.history[host] = self.history[host][-self.window:]

    def stragglers(self) -> List[str]:
        if len(self.history) < 3:
            return []
        means = {h: float(np.mean(v)) for h, v in self.history.items()}
        vals = np.array(list(means.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        return [h for h, m in means.items() if (m - med) / mad > self.k]


def elastic_plan(parallel: ParallelConfig, alive_hosts: int,
                 hosts_per_pod: Optional[int] = None) -> ParallelConfig:
    """Shrink the data axis to the largest power-of-two that the surviving
    hosts support.  Model axis is preserved (weight shards must all be
    present); if a model-axis host died its pod is dropped entirely."""
    import dataclasses
    total = parallel.pods * parallel.data * parallel.model
    if alive_hosts >= total:
        return parallel
    # drop pods first if multi-pod
    pods = parallel.pods
    while pods > 1 and alive_hosts < pods * parallel.data * parallel.model:
        pods -= 1
    data = parallel.data
    while data > 1 and alive_hosts < pods * data * parallel.model:
        data //= 2
    if alive_hosts < pods * data * parallel.model:
        raise RuntimeError(
            f"cannot form a mesh: {alive_hosts} hosts < minimal "
            f"{pods * data * parallel.model}")
    return dataclasses.replace(parallel, pods=pods, data=data)


@dataclass
class CadenceController:
    """Choose checkpoint cadence so E[lost work] <= budget_steps.

    With failure rate lambda (per step) and cadence c, expected loss per
    failure ~ c/2; E[lost per step] ~ lambda * c / 2.
    """
    budget_steps: float = 10.0
    min_cadence: int = 10
    max_cadence: int = 2000
    failures: List[int] = field(default_factory=list)
    steps_seen: int = 0

    def record_steps(self, n: int = 1):
        self.steps_seen += n

    def record_failure(self):
        self.failures.append(self.steps_seen)

    def cadence(self) -> int:
        if not self.failures or self.steps_seen == 0:
            return self.max_cadence
        lam = len(self.failures) / max(self.steps_seen, 1)
        c = int(2 * self.budget_steps / max(lam, 1e-9))
        return max(self.min_cadence, min(self.max_cadence, c))
