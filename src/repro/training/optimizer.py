"""AdamW with global-norm clipping and warmup+cosine schedule (optax-free)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * gf
        nu = b2 * nu + (1 - b2) * gf * gf
        upd_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        upd_ = upd_ + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd_).astype(p.dtype), mu, nu

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), \
        {"lr": lr, "grad_norm": gnorm}
