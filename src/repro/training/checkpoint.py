"""Atomic, resumable checkpointing (numpy-backed, orbax-free).

Layout:  <dir>/step_<n>/
             manifest.json          (step, leaf paths/dtypes/shapes, extras)
             arr_<i>.npy            one file per pytree leaf
         <dir>/LATEST               text file naming the newest step dir

Writes go to a tmp dir + atomic rename, so a host failure mid-save never
corrupts the restore point (fault-tolerance requirement).  Async saves run
on a daemon thread; `wait()` joins before the next save or exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, extras: Optional[dict] = None,
             async_: bool = False):
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef, extras),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, treedef, extras)

    def _write(self, step, host_leaves, treedef, extras):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "leaves": [{"dtype": str(a.dtype), "shape": list(a.shape)}
                       for a in host_leaves],
            "extras": extras or {},
            "time": time.time(),  # repro-lint: disable=raw-wall-clock (manifest timestamp)
        }
        for i, a in enumerate(host_leaves):
            # numpy can't (de)serialize ml_dtypes (bfloat16 etc.); store
            # raw bytes and reconstruct from the manifest dtype+shape
            if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
                a = np.ascontiguousarray(a).view(np.uint8)
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and ".tmp" not in d)
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ---------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``template`` (shapes must match).
        ``shardings`` optionally re-shards leaves on load (elastic resume
        onto a different mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(template)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
            a = np.load(os.path.join(d, f"arr_{i}.npy"))
            meta = manifest["leaves"][i]
            if a.dtype == np.uint8 and str(ref.dtype) == meta["dtype"] \
                    and np.dtype(ref.dtype).kind not in "u":
                a = a.view(np.dtype(str(ref.dtype))).reshape(meta["shape"])
            arr = jnp.asarray(a, dtype=ref.dtype)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree.unflatten(treedef, out), manifest
