"""Distributed train step: loss -> grads (with microbatch accumulation)
-> AdamW, under GSPMD shardings from the logical rule table."""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig, ShapeConfig, TrainConfig
from repro.training import optimizer as opt


class TrainState(NamedTuple):
    params: dict
    opt: opt.AdamWState


def init_train_state(model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=opt.init_adamw(params))


def make_loss_fn(model, cfg: ModelConfig):
    def loss_fn(params, batch):
        if cfg.is_encoder_decoder:
            return model.loss(params, batch["enc_embeds"], batch["tokens"],
                              batch["labels"])
        if cfg.modality == "vision_stub":
            logits = model.apply(params,
                                 inputs_embeds=batch["inputs_embeds"],
                                 positions=batch.get("positions"))
            labels = batch["labels"]
            mask = labels >= 0
            lab = jnp.maximum(labels, 0)
            lf = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(lf, axis=-1)
            gold = jnp.take_along_axis(lf, lab[..., None], axis=-1)[..., 0]
            return jnp.sum((logz - gold) * mask) / jnp.maximum(
                jnp.sum(mask), 1)
        return model.loss(params, batch["tokens"], batch["labels"])
    return loss_fn


def make_train_step(model, cfg: ModelConfig, parallel: ParallelConfig,
                    train_cfg: TrainConfig):
    loss_fn = make_loss_fn(model, cfg)
    n_micro = parallel.microbatches

    def train_step(state: TrainState, batch):
        if n_micro <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def split(x):
                return x.reshape((n_micro, x.shape[0] // n_micro)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        params, opt_state, om = opt.adamw_update(
            grads, state.opt, state.params, train_cfg)
        metrics = {"loss": loss, **om}
        return TrainState(params=params, opt=opt_state), metrics

    return train_step
