"""Pipeline parallelism (GPipe) over a mesh axis.

Layers are split into `n_stages` contiguous stages; stage s lives on the
mesh axis coordinate s.  Microbatches flow through a ppermute ring: at
schedule tick t, stage s processes microbatch t-s (the classic GPipe
schedule with (n_stages-1) bubble ticks on each side).

Used when ParallelConfig.pipeline_stages > 1, mapping the `pod` axis to
stages (DESIGN.md §4.1: memory-bound giants trade the pure-DP pod axis for
PP).  Forward-only building block exposed here; the train path wraps it
with jax.grad (XLA differentiates through ppermute).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import axis_size as _axis_size
from repro.core.compat import shard_map as _shard_map


def pipeline_body(stage_params, x_micro, *, stage_fn: Callable,
                  axis: str = "stage"):
    """shard_map body.  stage_params: this stage's params (leading layer
    dim already sliced); x_micro: (n_micro, mb, ...) full input (only
    stage 0 reads it).  Returns (n_micro, mb, ...) outputs (valid on every
    device after the trailing psum)."""
    idx = jax.lax.axis_index(axis)
    n = _axis_size(axis)
    n_micro = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]

    carry = jnp.zeros(mb_shape, x_micro.dtype)
    out = jnp.zeros_like(x_micro)
    perm = [(i, (i + 1) % n) for i in range(n)]

    for t in range(n_micro + n - 1):
        mb_idx = t - idx                      # traced (idx is traced)
        feed = x_micro[jnp.clip(t, 0, n_micro - 1)]
        inp = jnp.where(idx == 0, feed, carry)
        active = (mb_idx >= 0) & (mb_idx < n_micro)
        y = stage_fn(stage_params, inp)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # last stage banks its finished microbatch
        bank = jnp.where((idx == n - 1) & active, y, jnp.zeros_like(y))
        out = jax.lax.dynamic_update_slice(
            out, bank[None],
            (jnp.clip(mb_idx, 0, n_micro - 1),) + (0,) * len(mb_shape))
        carry = jax.lax.ppermute(y, axis, perm)
    # everyone gets the last stage's outputs
    return jax.lax.psum(jnp.where(idx == n - 1, out, jnp.zeros_like(out)),
                        axis)


def make_pipeline(mesh, stage_fn: Callable, *, axis: str = "stage",
                  params_spec=P("stage"), x_spec=P()):
    """Build a jit-able pipelined forward.

    stage_fn(stage_params, x) applies ONE stage's layers.  Stage params
    must have a leading stage dimension sharded over `axis`.
    """
    body = functools.partial(pipeline_body, stage_fn=lambda p, x:
                             stage_fn(jax.tree.map(lambda a: a[0], p), x),
                             axis=axis)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: params_spec, params_spec)
                  if not isinstance(params_spec, P) else params_spec,
                  x_spec),
        out_specs=x_spec,
        check_vma=False)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
