"""Gradient compression for data-parallel all-reduce (int8 + error feedback).

EF21-style: each step quantizes (grad + residual) to int8 with a per-tensor
scale, all-reduces the int8 payload (8x less ICI traffic than f32/4x less
than bf16), and keeps the quantization error as the next step's residual.
Off by default; enabled by ParallelConfig.grad_compression = "int8_ef".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size as _axis_size


def quantize_int8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compressed_psum(grads, residuals, axis_name: str):
    """shard_map body: error-feedback int8 all-reduce of local grads.

    Returns (reduced_grads_f32, new_residuals).
    """
    n = _axis_size(axis_name)

    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, scale = quantize_int8(v)
        new_r = v - dequantize_int8(q, scale)
        # sum int32 payloads; scales are tiny, reduce separately
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(scale, axis_name) / n
        return (qsum.astype(jnp.float32) * ssum / n), new_r

    out = jax.tree.map(one, grads, residuals)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return red, res
