"""Context-parallel decode: KV cache sharded along its sequence dimension.

Beyond-paper extension (DESIGN.md §2.6): the paper tiles the KV sequence
within one NPU; here the same online-softmax decomposition is promoted to
the distributed level.  Each `model`-axis shard holds a contiguous slice of
the KV cache, runs flash-decode locally with a log-sum-exp, and partial
outputs merge exactly:

    m  = pmax(lse_i)
    out = psum(exp(lse_i - m) * out_i) / psum(exp(lse_i - m) * l_i ... )

(the denominator folds into the weights since out_i is already normalized
by its local softmax sum).

This removes the per-device KV-cache replication that otherwise caps
context length -- the distributed analogue of the paper's 16K -> 256K
claim -- and is what makes decode_32k@b128 and long_500k fit on v5e.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

NEG_INF = -1e30


def _local_decode_with_lse(q, k, v, start, stop, *, window, softcap, scale,
                           global_len):
    """Decode attention over a local KV shard covering [start, stop).

    q: (B, Hq, D); k/v: (B, Hkv, S_local, D); returns (out, lse) where out
    is locally softmax-normalized and lse the local log-sum-exp.
    """
    b, hq, d = q.shape
    hkv, s_local = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if n_rep > 1:
        kf = jnp.repeat(kf, n_rep, axis=1)
        vf = jnp.repeat(vf, n_rep, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = start + jnp.arange(s_local)[None, None, :]
    glen = jnp.asarray(global_len).reshape(-1, 1, 1)
    valid = pos < glen
    if window is not None:
        valid = valid & (pos >= glen - window)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(jnp.where(valid, p, 0.0), axis=-1)
    l_safe = jnp.where(l == 0, 1.0, l)
    out = jnp.einsum("bhk,bhkd->bhd", p, vf) / l_safe[..., None]
    lse = jnp.where(l == 0, NEG_INF, m + jnp.log(l_safe))
    return out, lse


def cp_decode_body(q, k_shard, v_shard, kv_len, *, axis_name: str,
                   window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   scale: Optional[float] = None,
                   global_seq: int = 0):
    """shard_map body: q replicated, k/v sharded along seq on axis_name."""
    idx = jax.lax.axis_index(axis_name)
    s_local = k_shard.shape[2]
    start = idx * s_local
    out, lse = _local_decode_with_lse(
        q, k_shard, v_shard, start, start + s_local, window=window,
        softcap=softcap, scale=scale, global_len=kv_len)
    m = jax.lax.pmax(lse, axis_name)
    w = jnp.exp(lse - m)                                   # (B, Hq)
    num = jax.lax.psum(out * w[..., None], axis_name)
    den = jax.lax.psum(w, axis_name)
    den = jnp.where(den == 0, 1.0, den)
    return (num / den[..., None]).astype(q.dtype)


def context_parallel_decode(mesh, q, k_cache, v_cache, kv_len, *,
                            axis_name: str = "model",
                            batch_axes=("data",),
                            window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            scale: Optional[float] = None):
    """Distributed decode attention.

    q: (B, Hq, D); caches (B, Hkv, S, D) -- S sharded over ``axis_name``,
    B sharded over ``batch_axes``.  Returns (B, Hq, D).
    """
    body = functools.partial(
        cp_decode_body, axis_name=axis_name, window=window,
        softcap=softcap, scale=scale, global_seq=k_cache.shape[2])
    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None, axis_name, None),
                  P(ba, None, axis_name, None), P(ba)),
        out_specs=P(ba, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, kv_len)
