"""Context-parallel decode: KV cache sharded along its sequence dimension.

Beyond-paper extension (DESIGN.md §2.6): the paper tiles the KV sequence
within one NPU; here the same online-softmax decomposition is promoted to
the distributed level.  Each `model`-axis shard holds a contiguous slice of
the KV cache, runs flash-decode locally with a log-sum-exp, and partial
outputs merge exactly:

    m  = pmax(lse_i)
    out = psum(exp(lse_i - m) * out_i) / psum(exp(lse_i - m) * l_i ... )

(the denominator folds into the weights since out_i is already normalized
by its local softmax sum).

This removes the per-device KV-cache replication that otherwise caps
context length -- the distributed analogue of the paper's 16K -> 256K
claim -- and is what makes decode_32k@b128 and long_500k fit on v5e.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map

NEG_INF = -1e30


def attend_with_positions(q, k, v, *, q_positions, kv_positions, kv_len,
                          causal: bool = True,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None,
                          scale: Optional[float] = None):
    """Attention over a KV slice whose global token positions are
    arbitrary (the paged-TP building block).

    A page-row sub-shard's gathered KV view is *strided* in global
    positions (it holds rows ``[si*ps_l, (si+1)*ps_l)`` of every page),
    so masks must be driven by an explicit position vector rather than
    an offset + arange.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, K, D); q_positions: (B, Sq) int32
    global query positions; kv_positions: (K,) int32 global key
    positions; kv_len: (B,) int32 valid global lengths.  Returns
    ``(out, lse)`` -- out (B, Hq, Sq, D) f32, locally softmax-
    normalized; lse (B, Hq, Sq) the local log-sum-exp, NEG_INF where no
    key was valid (so the cross-shard merge weighs the shard at zero).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if n_rep > 1:
        kf = jnp.repeat(kf, n_rep, axis=1)
        vf = jnp.repeat(vf, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = kv_positions.astype(jnp.int32)[None, None, :]     # (1, 1, K)
    q_pos = q_positions.astype(jnp.int32)[:, :, None]          # (B, Sq, 1)
    mask = kv_pos < jnp.asarray(kv_len, jnp.int32).reshape(-1, 1, 1)
    if causal:
        mask = mask & (q_pos >= kv_pos)
    if window is not None:
        mask = mask & (q_pos - kv_pos < window)
    maskb = mask[:, None]                                # (B, 1, Sq, K)
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(jnp.where(maskb, p, 0.0), axis=-1)
    l_safe = jnp.where(l == 0, 1.0, l)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf) / l_safe[..., None]
    lse = jnp.where(l == 0, NEG_INF, m + jnp.log(l_safe))
    return out, lse


def merge_partial_attention(out, lse, axis_name):
    """Exact cross-shard merge of locally-normalized partial attention.

    The log-sum-exp combination (module docstring): ``m = pmax(lse);
    w = exp(lse - m); psum(out * w) / psum(w)``.  ``axis_name`` may be a
    tuple of mesh axes or carry ``axis_index_groups`` semantics via a
    sub-axis of a 2-D mesh (the paged-TP path merges over the page-row
    axis only, within each kv-head group).  out: lse.shape + (D,).
    """
    m = jax.lax.pmax(lse, axis_name)
    w = jnp.exp(lse - m)
    num = jax.lax.psum(out * w[..., None], axis_name)
    den = jax.lax.psum(w, axis_name)
    den = jnp.where(den == 0, 1.0, den)
    return num / den[..., None]


# ---------------------------------------------------------------------------
# Paged entry points (the TP serving path's shard_map-body helpers)
# ---------------------------------------------------------------------------

def paged_local_view(pages, page_table):
    """Local analogue of kernels/flash_decode/ref.paged_gather for one
    shard's pool block: pages (Hkv_local, P, ps_local, D), page_table
    (B, n_kv) int32 -> (B, Hkv_local, n_kv * ps_local, D)."""
    g = pages[:, page_table]                 # (H, B, n_kv, ps_l, D)
    h, b, n_kv, psl, d = g.shape
    return g.transpose(1, 0, 2, 3, 4).reshape(b, h, n_kv * psl, d)


def paged_shard_kv_positions(n_kv: int, page_size: int, rows_local: int,
                             shard_index):
    """Global token position of every row of a page-row sub-shard's
    gathered view: view row j sits in logical page ``j // rows_local``
    at within-page offset ``shard_index * rows_local + j % rows_local``.
    ``shard_index`` may be a traced ``axis_index``.  Returns (K,) int32
    with K = n_kv * rows_local."""
    j = jnp.arange(n_kv * rows_local, dtype=jnp.int32)
    return ((j // rows_local) * page_size
            + shard_index * rows_local + j % rows_local)


def _local_decode_with_lse(q, k, v, start, stop, *, window, softcap, scale,
                           global_len):
    """Decode attention over a local KV shard covering [start, stop).

    q: (B, Hq, D); k/v: (B, Hkv, S_local, D); returns (out, lse) where out
    is locally softmax-normalized and lse the local log-sum-exp.
    """
    glen = jnp.asarray(global_len, jnp.int32).reshape(-1)
    kv_pos = start + jnp.arange(k.shape[2], dtype=jnp.int32)
    # decode masks (pos < len, window back from len) are the causal/
    # window masks at q_position = len - 1
    out, lse = attend_with_positions(
        q[:, :, None], k, v, q_positions=(glen - 1)[:, None],
        kv_positions=kv_pos, kv_len=glen, causal=True, window=window,
        softcap=softcap, scale=scale)
    return out[:, :, 0], lse[:, :, 0]


def cp_decode_body(q, k_shard, v_shard, kv_len, *, axis_name: str,
                   window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   scale: Optional[float] = None,
                   global_seq: int = 0):
    """shard_map body: q replicated, k/v sharded along seq on axis_name."""
    idx = jax.lax.axis_index(axis_name)
    s_local = k_shard.shape[2]
    start = idx * s_local
    out, lse = _local_decode_with_lse(
        q, k_shard, v_shard, start, start + s_local, window=window,
        softcap=softcap, scale=scale, global_len=kv_len)
    return merge_partial_attention(out, lse, axis_name).astype(q.dtype)


def context_parallel_decode(mesh, q, k_cache, v_cache, kv_len, *,
                            axis_name: str = "model",
                            batch_axes=("data",),
                            window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            scale: Optional[float] = None):
    """Distributed decode attention.

    q: (B, Hq, D); caches (B, Hkv, S, D) -- S sharded over ``axis_name``,
    B sharded over ``batch_axes``.  Returns (B, Hq, D).
    """
    body = functools.partial(
        cp_decode_body, axis_name=axis_name, window=window,
        softcap=softcap, scale=scale, global_seq=k_cache.shape[2])
    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None, axis_name, None),
                  P(ba, None, axis_name, None), P(ba)),
        out_specs=P(ba, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, kv_len)
