"""Public FastAttention API used by the model layers.

Model layers use (B, S, H, D) activations; kernels use (B, H, S, D).
This facade handles the transposition, implementation dispatch and the
serve-time (decode) path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fast_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True,
                   window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   scale: Optional[float] = None,
                   q_offset: int = 0,
                   kv_valid: Optional[int] = None,
                   impl: str = "reference",
                   block_q: int = 256,
                   block_kv1: int = 1024,
                   block_kv2: int = 256) -> jax.Array:
    """Attention over (B, S, H, D) tensors.  Returns (B, Sq, Hq, D).

    ``kv_valid`` (static) masks K/V rows past that length (e.g. the
    zero-padded tail of a gathered paged view)."""
    from repro.kernels.fastattn.ops import fastattn
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    out = fastattn(qT, kT, vT, causal, window, softcap, scale, q_offset,
                   block_q, block_kv1, block_kv2, impl, kv_valid)
    return out.transpose(0, 2, 1, 3)


def default_paged_impl() -> str:
    """Best paged-decode impl for the current backend: the Pallas kernel
    on TPU, the jittable gather-reference everywhere else (the kernel
    still runs off-TPU via interpret=True, but only for verification)."""
    return "paged" if jax.default_backend() == "tpu" else "paged_reference"


def fast_attention_prefill_paged(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array, page_table: jax.Array,
                                 pos_start: jax.Array, kv_len: jax.Array, *,
                                 window: Optional[int] = None,
                                 softcap: Optional[float] = None,
                                 scale: Optional[float] = None,
                                 impl: str = "paged_reference",
                                 block_q: int = 256) -> jax.Array:
    """Chunked-prefill attention of one prompt chunk against the paged
    KV pools (the chunk's own K/V rows must already be scattered in).

    q: (B, Sq, Hq, D) layer-layout chunk queries; pages
    (Hkv, P, page_size, D); page_table (B, n_kv) int32; pos_start /
    kv_len: (B,) int32 *runtime* offsets -- one jit trace serves every
    chunk position of every prompt length.  "paged" runs the Pallas
    kernel (scalar-prefetched page table, auto-interpret off TPU);
    "paged_reference" gathers the owned pages and runs the online-softmax
    flash reference -- the jittable CPU path.  Returns (B, Sq, Hq, D).
    """
    qT = q.transpose(0, 2, 1, 3)
    if impl == "paged_reference":
        from repro.kernels.flash_decode.ref import paged_prefill_reference
        out = paged_prefill_reference(
            qT, k_pages, v_pages, page_table, pos_start, kv_len,
            window=window, softcap=softcap, scale=scale)
    elif impl in ("paged", "paged_interpret"):
        from repro.kernels.fastattn.ops import fastattn_paged_prefill
        interpret = (impl == "paged_interpret"
                     or jax.default_backend() != "tpu")
        out = fastattn_paged_prefill(
            qT, k_pages, v_pages, page_table, pos_start, kv_len,
            window=window, softcap=softcap, scale=scale, block_q=block_q,
            interpret=interpret)
    else:
        raise ValueError(f"unknown paged prefill impl {impl!r}")
    return out.transpose(0, 2, 1, 3)


def fast_attention_decode(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, kv_len: jax.Array, *,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None,
                          scale: Optional[float] = None,
                          impl: str = "reference",
                          block_kv: int = 512,
                          layout: str = "bshd",
                          page_table: Optional[jax.Array] = None
                          ) -> jax.Array:
    """Single-token decode attention.

    q: (B, 1, Hq, D); caches (B, S, Hkv, D) ["bshd"] or (B, Hkv, S, D)
    ["bhsd", head-major: no transpose before the contraction]; kv_len (B,).
    Returns (B, 1, Hq, D).

    With ``impl in ("paged", "paged_interpret", "paged_reference")`` the
    caches are instead global page pools (Hkv, P, page_size, D) shared by
    every sequence, and ``page_table`` (B, n_kv) int32 maps each
    sequence's logical KV block to its physical page (serving/paged_cache
    owns the table).  "paged" runs the Pallas kernel (auto interpret off
    TPU); "paged_reference" gathers the owned pages into a dense view and
    reuses the dense oracle -- the jittable CPU path.

    The reference path works IN PLACE on the (B, S, Hkv, D) bf16 cache --
    no transpose, no GQA expansion, no f32 copy; einsums accumulate in f32
    (decode is HBM-bound: every extra cache copy doubles the memory term).
    The sequence dim may carry the `kv_seq -> model` sharding; XLA then
    decomposes the max/sum/PV reductions into the LSE-merge collectives of
    core/distributed_decode.py.
    """
    if impl in ("paged", "paged_interpret", "paged_reference"):
        if page_table is None:
            raise ValueError(f"impl={impl!r} requires a page_table")
        if impl == "paged_reference":
            from repro.kernels.flash_decode.ref import paged_decode_reference
            out = paged_decode_reference(
                q.transpose(0, 2, 1, 3), k_cache, v_cache, page_table,
                kv_len, window=window, softcap=softcap, scale=scale)
            return out.transpose(0, 2, 1, 3)
        from repro.kernels.flash_decode.ops import paged_flash_decode
        interpret = (impl == "paged_interpret"
                     or jax.default_backend() != "tpu")
        out = paged_flash_decode(
            q.transpose(0, 2, 1, 3)[:, :, 0], k_cache, v_cache, page_table,
            kv_len, window=window, softcap=softcap, scale=scale,
            interpret=interpret)[:, :, None]
        return out.transpose(0, 2, 1, 3)

    if impl in ("pallas", "interpret"):
        from repro.kernels.flash_decode.ops import flash_decode
        qT = q.transpose(0, 2, 1, 3)
        if layout == "bhsd":
            kT, vT = k_cache, v_cache
        else:
            kT = k_cache.transpose(0, 2, 1, 3)
            vT = v_cache.transpose(0, 2, 1, 3)
        out = flash_decode(qT[:, :, 0], kT, vT, kv_len,
                           window=window, softcap=softcap, scale=scale,
                           block_kv=block_kv,
                           interpret=(impl == "interpret"))[:, :, None]
        return out.transpose(0, 2, 1, 3)

    b, _, hq, d = q.shape
    if layout == "bhsd":
        hkv, s = k_cache.shape[1], k_cache.shape[2]
    else:
        s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    kv_eq = "bhgd,bhsd->bhgs" if layout == "bhsd" else "bhgd,bshd->bhgs"
    logits = jnp.einsum(kv_eq, qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(s)[None, None, None, :]
    lens = jnp.asarray(kv_len).reshape(b, 1, 1, 1)
    mask = pos < lens
    if window is not None:
        mask = mask & (pos >= lens - window)
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = (p / jnp.where(l == 0, 1.0, l)).astype(k_cache.dtype)
    pv_eq = "bhgs,bhsd->bhgd" if layout == "bhsd" else "bhgs,bshd->bhgd"
    out = jnp.einsum(pv_eq, p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)
