"""The paper's contribution: FastAttention core (T1-T4).

T1 two-level tiling      -> kernels/fastattn + core/tiling.py
T2 tiling-mask           -> core/tiling_mask.py
T3 tiling-AllReduce      -> core/tiled_allreduce.py
T4 CPU-GPU cooperative   -> core/offload.py
beyond-paper CP decode   -> core/distributed_decode.py
"""
from repro.core.fastattention import fast_attention, fast_attention_decode  # noqa: F401
