"""Paper §4.2: tiling-AllReduce (T3).

In tensor-parallel inference every layer ends in ``partial = x @ W_row``
followed by an AllReduce.  The paper splits the B*S dimension into blocks
and issues one *B-allreduce* per block so communication of block i overlaps
compute of block i+1 (SDMA on Ascend; async ICI collectives + the XLA
latency-hiding scheduler on TPU).  Two paper details are preserved:

  * the FIRST block is smaller (``first_chunk_frac``) -- its AllReduce is
    the only one that cannot be overlapped, so shrinking it shrinks the
    exposed latency (paper: "assign smaller computation tasks to the first
    block");
  * the chunk count is bounded so per-block payloads stay large enough to
    saturate link bandwidth (paper: "enlarge the block size to achieve
    better bandwidth utilization").

Entry points:
  tiled_matmul_allreduce   -- chunked row-parallel matmul + psum (shard_map
                              body; works for O-proj and MLP down-proj).
  fused_attention_linear   -- the paper's fused attention+Linear+B-allreduce
                              block (head-sharded TP, benchmark/operator use).
  ring variant             -- explicit ppermute ring for scheduler-independent
                              overlap.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import axis_size as _axis_size
from repro.core.compat import shard_map as _shard_map


def chunk_sizes(total: int, n_chunks: int, first_frac: float = 0.5,
                align: int = 1) -> Sequence[int]:
    """Split ``total`` into ``n_chunks`` pieces, the first scaled by
    ``first_frac`` (paper: smaller head block), EVERY piece a multiple of
    ``align``.

    The split is computed in units of ``align`` so the trailing chunk is
    aligned too -- the old code appended a raw remainder, handing
    ``ring_matmul_allreduce`` (``piece = s // n``) and
    ``tiled_matmul_reducescatter`` (``psum_scatter`` needs axis-divisible
    chunks) a chunk they silently mis-split.  ``total`` itself must be a
    multiple of ``align``; callers with ragged totals pad first and slice
    the result (see ``ring_matmul_allreduce``).
    """
    align = max(align, 1)
    if total <= 0:
        raise ValueError(f"total must be positive, got {total}")
    if total % align:
        raise ValueError(
            f"total={total} is not a multiple of align={align}; pad the "
            f"leading dim to a multiple first and slice the result")
    units = total // align
    n_chunks = max(1, min(n_chunks, units))
    if n_chunks == 1:
        sizes = [total]
    else:
        base = units / (n_chunks - 1 + first_frac)
        first = min(max(1, int(base * first_frac)),
                    units - (n_chunks - 1))
        sizes = [first * align]
        remaining = units - first
        for i in range(n_chunks - 2):
            su = max(1, int(base))
            su = min(su, remaining - (n_chunks - 2 - i))
            sizes.append(su * align)
            remaining -= su
        sizes.append(remaining * align)
    assert sum(sizes) == total, sizes
    assert all(s > 0 for s in sizes), sizes
    assert all(s % align == 0 for s in sizes), sizes
    return sizes


def tiled_matmul_allreduce(x: jax.Array, w: jax.Array, axis_name: str, *,
                           n_chunks: int = 4, first_chunk_frac: float = 0.5,
                           precision=None) -> jax.Array:
    """psum_over_axis(x @ w), chunked over the leading dim of x.

    Per-device shard_map body.  x: (T, F_local); w: (F_local, D).
    Equivalent to ``jax.lax.psum(x @ w, axis_name)`` but emits one
    all-reduce per chunk, each overlappable with the next chunk's matmul.
    """
    t = x.shape[0]
    sizes = chunk_sizes(t, n_chunks, first_chunk_frac)
    outs = []
    off = 0
    for s in sizes:
        y = jax.lax.dynamic_slice_in_dim(x, off, s, 0) @ w
        outs.append(jax.lax.psum(y, axis_name))     # B-allreduce
        off += s
    return jnp.concatenate(outs, axis=0)


def single_matmul_allreduce(x: jax.Array, w: jax.Array,
                            axis_name: str) -> jax.Array:
    """Baseline: unfused matmul + one monolithic AllReduce."""
    return jax.lax.psum(x @ w, axis_name)


def matmul_allreduce(x: jax.Array, w: jax.Array, axis_name, *,
                     mode: str = "tiled", n_chunks: int = 4,
                     first_chunk_frac: float = 0.5) -> jax.Array:
    """Row-parallel matmul + AllReduce, dispatching on ``mode``.

    The shard_map-body entry point the tensor-parallel serving path uses
    for O-proj / down-proj partial sums.  ``axis_name`` may be a tuple of
    mesh axes (the paged TP mesh reduces over both its kv-head-group and
    page-row axes at once).  ``mode="tiled"`` emits one psum per chunk of
    the token dim (paper T3, overlappable); ``"single"`` is the
    monolithic baseline the benchmark compares against.
    """
    if mode == "single":
        return single_matmul_allreduce(x, w, axis_name)
    if mode != "tiled":
        raise ValueError(f"unknown allreduce mode {mode!r} "
                        "(expected 'tiled' or 'single')")
    return tiled_matmul_allreduce(x, w, axis_name, n_chunks=n_chunks,
                                  first_chunk_frac=first_chunk_frac)


def tiled_matmul_reducescatter(x: jax.Array, w: jax.Array, axis_name: str, *,
                               n_chunks: int = 4,
                               first_chunk_frac: float = 0.5) -> jax.Array:
    """Chunked row-parallel matmul + reduce-scatter (sequence-parallel TP).

    Output rows are scattered along the axis: (T, D) -> (T/axis, D).
    """
    t = x.shape[0]
    axis_size = _axis_size(axis_name)
    if t % axis_size:
        raise ValueError(
            f"tiled_matmul_reducescatter: leading dim {t} must divide the "
            f"axis size {axis_size} -- psum_scatter splits every chunk "
            f"evenly over the axis; pad the rows first")
    sizes = chunk_sizes(t, n_chunks, first_chunk_frac, align=axis_size)
    outs = []
    off = 0
    for s in sizes:
        y = jax.lax.dynamic_slice_in_dim(x, off, s, 0) @ w
        outs.append(jax.lax.psum_scatter(y, axis_name, scatter_dimension=0,
                                         tiled=True))
        off += s
    return jnp.concatenate(outs, axis=0)


def ring_matmul_allreduce(x: jax.Array, w: jax.Array, axis_name: str, *,
                          n_chunks: int = 4) -> jax.Array:
    """Explicit overlap variant: reduce-scatter ring interleaved with the
    per-chunk matmuls, then all-gather.  The ppermute of chunk i runs while
    chunk i+1's matmul executes -- scheduler-independent overlap.

    Rows are padded to a multiple of the axis size (each chunk ring-
    scatters into ``s // n`` pieces) and the pad sliced off the result,
    so ragged token counts stay exact.
    """
    t = x.shape[0]
    n = _axis_size(axis_name)
    pad = (-t) % n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    sizes = chunk_sizes(t + pad, n_chunks, 1.0, align=n)
    outs = []
    off = 0
    for s in sizes:
        y = jax.lax.dynamic_slice_in_dim(x, off, s, 0) @ w   # (s, D)
        # ring reduce-scatter over n-1 steps on this chunk; device i ends
        # holding fully-reduced piece i, so the trailing all-gather tiles
        # back in order.
        piece = s // n
        acc = jax.lax.dynamic_slice_in_dim(
            y, ((idx - 1) % n) * piece, piece, 0)
        for step in range(1, n):
            acc = jax.lax.ppermute(acc, axis_name, perm)
            src = jax.lax.dynamic_slice_in_dim(
                y, ((idx - step - 1) % n) * piece, piece, 0)
            acc = acc + src
        outs.append(jax.lax.all_gather(acc, axis_name, axis=0, tiled=True))
        off += s
    out = jnp.concatenate(outs, axis=0)
    return out[:t] if pad else out


def fused_attention_linear(q, k, v, w_o, axis_name: str, *,
                           n_chunks: int = 4, first_chunk_frac: float = 0.5,
                           causal: bool = True,
                           softcap: Optional[float] = None,
                           attention_fn: Optional[Callable] = None,
                           mode: str = "tiled") -> jax.Array:
    """Paper Fig. 4: fused attention + Linear + B-allreduce.

    Head-sharded TP shard_map body: q (B, S, H_local, D), k/v
    (B, S, Hkv_local, D), w_o (H_local*D, d_model).  The B*S dimension is
    split into blocks; each block runs attention -> O-proj -> B-allreduce,
    with block i's allreduce overlapping block i+1's compute.
    """
    from repro.core.fastattention import fast_attention
    b, s, h, d = q.shape
    attention_fn = attention_fn or (
        lambda qq, kk, vv, off: fast_attention(
            qq, kk, vv, causal=causal, softcap=softcap, q_offset=off,
            impl="reference"))
    if mode == "single":
        o = attention_fn(q, k, v, 0).reshape(b, s, h * d)
        return jax.lax.psum(o @ w_o, axis_name)
    # tile along S (paper tiles along B*S; S keeps causal offsets simple)
    sizes = chunk_sizes(s, n_chunks, first_chunk_frac)
    outs = []
    off = 0
    for sz in sizes:
        q_c = jax.lax.dynamic_slice_in_dim(q, off, sz, 1)
        kv_end = off + sz if causal else s
        k_c = jax.lax.dynamic_slice_in_dim(k, 0, kv_end, 1)
        v_c = jax.lax.dynamic_slice_in_dim(v, 0, kv_end, 1)
        o_c = attention_fn(q_c, k_c, v_c, off).reshape(b, sz, h * d)
        outs.append(jax.lax.psum(o_c @ w_o, axis_name))   # B-allreduce
        off += sz
    return jnp.concatenate(outs, axis=1)


def make_sharded_fused_block(mesh, axis_name: str = "model", **kw):
    """shard_map-wrapped fused_attention_linear over head-sharded inputs."""
    fn = functools.partial(fused_attention_linear, axis_name=axis_name, **kw)
    return _shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, None, axis_name, None),
                  P(None, None, axis_name, None),
                  P(None, None, axis_name, None),
                  P(axis_name, None)),
        out_specs=P(None, None, None),
        check_vma=False)
