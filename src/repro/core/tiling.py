"""Two-level tiling planner (paper §4.1, adapted to TPU VMEM/MXU).

The paper picks its first-level block size from the Ascend L1 buffer and
its second-level block size from L0; we re-derive both from the TPU memory
hierarchy: level 1 fills VMEM (minus double-buffering headroom), level 2
aligns to the 128x128 MXU.
"""
from __future__ import annotations

from dataclasses import dataclass

# v5e-class constants (also used by analysis/roofline.py)
VMEM_BYTES = 64 * 1024 * 1024       # usable VMEM budget per core (conservative)
MXU_DIM = 128
LANES = 128


@dataclass(frozen=True)
class TilingPlan:
    block_q: int
    block_kv1: int          # level-1: HBM -> VMEM macro block
    block_kv2: int          # level-2: MXU-aligned sub tile
    m_mask: int             # M of the (2M)^2 tiling-mask
    vmem_bytes: int         # estimated VMEM working set

    @property
    def n_sub(self) -> int:
        return self.block_kv1 // self.block_kv2


def vmem_working_set(block_q: int, block_kv1: int, block_kv2: int,
                     head_dim: int, dtype_bytes: int = 2) -> int:
    """VMEM bytes for one grid step of the fastattn kernel.

    Q block + double-buffered K/V macro blocks + f32 accumulators + M-mask.
    """
    mm = max(block_q, block_kv2)
    q = block_q * head_dim * dtype_bytes
    kv = 2 * 2 * block_kv1 * head_dim * dtype_bytes    # K,V double-buffered
    acc = block_q * head_dim * 4
    stats = 2 * block_q * LANES * 4
    mask = (2 * mm) * (2 * mm)
    out = block_q * head_dim * dtype_bytes * 2
    return q + kv + acc + stats + mask + out


def plan_two_level_tiling(seq_q: int, seq_kv: int, head_dim: int, *,
                          dtype_bytes: int = 2,
                          vmem_budget: int = VMEM_BYTES,
                          max_block_q: int = 512,
                          max_block_kv1: int = 4096) -> TilingPlan:
    """Choose (block_q, block_kv1, block_kv2) for a problem shape.

    Mirrors the paper's reasoning: grow the level-1 block until the memory
    budget (here VMEM, there L1) is exhausted -- larger level-1 blocks mean
    fewer pipeline synchronizations and better HBM bandwidth utilization --
    while the level-2 block stays at the compute unit's native tile.
    """
    block_kv2 = MXU_DIM if head_dim >= 128 else 2 * MXU_DIM
    block_q = min(max_block_q, _round_up(min(seq_q, 256), 8))
    # grow level-1 block while it fits
    block_kv1 = block_kv2
    while (block_kv1 * 2 <= max_block_kv1
           and block_kv1 * 2 <= _round_up(seq_kv, block_kv2)
           and vmem_working_set(block_q, block_kv1 * 2, block_kv2,
                                head_dim, dtype_bytes) <= vmem_budget):
        block_kv1 *= 2
    plan = TilingPlan(
        block_q=block_q,
        block_kv1=block_kv1,
        block_kv2=block_kv2,
        m_mask=max(block_q, block_kv2),
        vmem_bytes=vmem_working_set(block_q, block_kv1, block_kv2,
                                    head_dim, dtype_bytes),
    )
    return plan


def sync_count(seq_kv: int, block: int) -> int:
    """Number of pipeline boundaries ('synchronizations') for a KV pass --
    the quantity the paper's level-1 enlargement minimizes."""
    return (seq_kv + block - 1) // block


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
