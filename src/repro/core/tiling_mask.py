"""Paper §4.1 tiling-mask strategy (T2).

Replaces the S x S ``attention_mask`` with a single (2M) x (2M) *M-mask*
from which the *B-mask* of any ``bq x bk`` attention-score block can be
recovered as a shifted slice, because a causal (or banded) mask block only
depends on ``delta = q_start - kv_start``:

    M[u, v] = (u >= v)                       (lower-triangular M-mask)
    B[r, c] = (delta + r >= c)
            = M[max(delta,0) + r, max(-delta,0) + c]     for |delta| < M

Sliding-window (banded) masks are the AND of two shifted slices of the SAME
M-mask:  visible(q,k) = (q >= k) & (q - k < w)
                      = slice(M, delta)[r,c] & ~slice(M, delta - w)[r,c].

Block classification drives the paper's two skip optimizations:
  * SKIP (all-masked)  -> don't compute the block at all (~50% of Cube work
    for causal attention);
  * FULL (all-visible) -> skip the mask add (Vector-unit saving);
  * PARTIAL            -> apply the sliced B-mask.

Memory: an S=64K causal mask in fp16 is 8 GB; the M-mask for M=512 is
(1024)^2 int8 = 1 MB (256 KB as bits) -- the paper's Table numbers.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Block classifications.
SKIP, PARTIAL, FULL = 0, 1, 2


@functools.lru_cache(maxsize=8)
def _m_mask_np(m: int) -> np.ndarray:
    u = np.arange(2 * m)
    return (u[:, None] >= u[None, :]).astype(np.int8)


def make_m_mask(m: int, dtype=jnp.int8) -> jax.Array:
    """The (2M, 2M) lower-triangular M-mask (paper Fig. 3)."""
    return jnp.asarray(_m_mask_np(m), dtype=dtype)


def bmask_offsets(delta, m: int, bq: int, bk: int):
    """Start offsets of the B-mask slice inside the M-mask for shift delta."""
    row0 = jnp.clip(delta, 0, 2 * m - bq)
    col0 = jnp.clip(-delta, 0, 2 * m - bk)
    return row0, col0


def slice_bmask(m_mask: jax.Array, delta, bq: int, bk: int) -> jax.Array:
    """Extract the (bq, bk) B-mask for ``delta = q_start - kv_start``.

    Exact whenever the block is PARTIAL (|delta| < M); clamped otherwise
    (callers must classify first -- SKIP/FULL blocks never consult the mask).
    """
    m = m_mask.shape[0] // 2
    row0, col0 = bmask_offsets(delta, m, bq, bk)
    return jax.lax.dynamic_slice(m_mask, (row0, col0), (bq, bk))


def slice_band_bmask(m_mask: jax.Array, delta, window: int,
                     bq: int, bk: int) -> jax.Array:
    """B-mask for causal+sliding-window: slice(δ) & ~slice(δ - window)."""
    causal = slice_bmask(m_mask, delta, bq, bk)
    lower = slice_bmask(m_mask, delta - window, bq, bk)
    return causal * (1 - lower)


def classify_block(q_start, kv_start, bq: int, bk: int, *,
                   causal: bool = True, window: Optional[int] = None,
                   kv_len=None):
    """Classify a (bq, bk) score block as SKIP / PARTIAL / FULL.

    Works on python ints or traced values.  ``kv_len`` optionally marks KV
    padding (positions >= kv_len are masked).
    """
    q_end = q_start + bq - 1
    kv_end = kv_start + bk - 1
    full = True
    skip = False
    if causal:
        delta = q_start - kv_start
        skip = skip | (delta <= -bq) if not isinstance(skip, bool) or skip \
            else (delta <= -bq)
        full = full & (delta >= bk - 1)
    if window is not None:
        # visible requires k > q - w; fully masked if kv_end <= q_start - w
        skip = skip | (kv_end <= q_start - window)
        full = full & (kv_start >= q_end - window + 1)
    if kv_len is not None:
        skip = skip | (kv_start >= kv_len)
        full = full & (kv_end < kv_len)
    if isinstance(skip, (bool, np.bool_)):
        return SKIP if skip else (FULL if full else PARTIAL)
    return jnp.where(skip, SKIP, jnp.where(full, FULL, PARTIAL))


class MaskSpec(NamedTuple):
    """Static description of the mask pattern for a kernel launch."""
    causal: bool = True
    window: Optional[int] = None     # sliding window width (includes self)
    q_offset: int = 0                # global position of q row 0 (decode)

    def block_limits(self, n_q_blocks: int, n_kv_blocks: int,
                     bq: int, bk: int, kv_len: int):
        """Per-q-block [first, last] valid kv-block indices (numpy, static)."""
        first = np.zeros(n_q_blocks, np.int64)
        last = np.full(n_q_blocks, n_kv_blocks - 1, np.int64)
        for qi in range(n_q_blocks):
            q0 = self.q_offset + qi * bq
            qe = q0 + bq - 1
            if self.causal:
                last[qi] = min(last[qi], qe // bk)
            if self.window is not None:
                first[qi] = max(first[qi], (q0 - self.window + 1) // bk)
            last[qi] = min(last[qi], max((kv_len - 1) // bk, 0))
            first[qi] = max(min(first[qi], last[qi]), 0)
        return first, last


def mask_memory_bytes(seq_len: int, dtype_bytes: int = 2) -> int:
    """Memory of a dense S x S mask (the paper's 8 GB @ 64K example)."""
    return seq_len * seq_len * dtype_bytes


def m_mask_memory_bytes(m: int, dtype_bytes: int = 1) -> int:
    return (2 * m) * (2 * m) * dtype_bytes


def dense_mask(seq_q: int, seq_k: int, *, causal: bool = True,
               window: Optional[int] = None, q_offset: int = 0) -> jax.Array:
    """Reference dense mask (oracle for property tests)."""
    q = jnp.arange(seq_q)[:, None] + q_offset
    k = jnp.arange(seq_k)[None, :]
    m = jnp.ones((seq_q, seq_k), jnp.bool_)
    if causal:
        m = m & (q >= k)
    if window is not None:
        m = m & (q - k < window)
    return m
