"""Version shims for the jax API surface used by the distribution code.

Targets the current public API (``jax.shard_map`` with ``check_vma``)
while staying runnable on the older jaxlibs found in CPU-only CI
containers (``jax.experimental.shard_map.shard_map`` with ``check_rep``).
"""
from __future__ import annotations

import jax

try:                                      # jax >= 0.6 public API
    _impl = jax.shard_map
    _LEGACY = False
except AttributeError:                    # older jax: experimental module
    from jax.experimental.shard_map import shard_map as _impl
    _LEGACY = True


def shard_map(*args, **kwargs):
    if _LEGACY and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _impl(*args, **kwargs)


def axis_size(axis_name):
    """jax.lax.axis_size, or its psum(1) equivalent on older jax (only
    valid inside shard_map/pmap bodies, same contract as the real one)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
