"""Paper §4.4: fine-grained CPU-GPU (host-TPU) cooperative strategy (T4).

Implements the paper's closed-form layer split (Eq. 15-20): the first
``L_CPU`` layers keep their KV cache in host memory and run decode
attention ON THE HOST (moving compute to the data); the remaining
``L_GPU`` layers keep KV on-device.  Only the fixed-size Q/attention-output
cross PCIe each decode step -- never the KV cache, which is what makes
this 1.27-1.48x faster than classical offloading in the paper's Table 3.

The planner and latency model are exact re-implementations of the paper's
formulas with hardware constants as parameters; the execution engine uses
JAX's CPU backend as the host and works on any device topology.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig


@dataclass(frozen=True)
class OffloadPlan:
    l_gpu: int                  # layers with on-device KV
    l_cpu: int                  # layers with host KV + host attention
    bytes_weights: int          # M_w   (total model weights)
    bytes_kv_layer: int         # M_kv  (per layer, per device)
    bytes_mid: int              # M_mid (intermediate, per device)
    bytes_vocab: int            # M_vocab
    device_budget: int          # M_GPU
    needs_offload: bool

    def summary(self) -> str:
        return (f"L_GPU={self.l_gpu} L_CPU={self.l_cpu} "
                f"(weights={self.bytes_weights/2**30:.2f}GiB "
                f"kv/layer/dev={self.bytes_kv_layer/2**20:.1f}MiB "
                f"mid={self.bytes_mid/2**20:.1f}MiB "
                f"offload={'yes' if self.needs_offload else 'no'})")


def plan_offload(cfg: ModelConfig, *, batch: int, seq_len: int,
                 gen_len: int, n_devices: int,
                 device_memory_gb: float = 16.0,
                 dtype_bytes: int = 2) -> OffloadPlan:
    """Paper Eq. 15-20 generalized to arbitrary architectures.

      L_GPU = (M_GPU - M_w/n - M_mid - M_vocab) / M_kv ;  L_CPU = L - L_GPU

    M_w uses the real per-layer parameter model (incl. GQA/MoE) instead of
    the paper's 8H1^2 + 4H1H2 (which assumes MHA + 2-matrix FFN); for MHA
    dense models the two coincide.
    """
    from repro.analysis.flops import param_count
    n = n_devices
    L = cfg.num_layers
    h1 = cfg.d_model
    m_vocab = cfg.vocab_size * h1 * dtype_bytes
    n_embed_mats = 1 if cfg.tie_embeddings else 2
    m_w = (param_count(cfg) - n_embed_mats * cfg.vocab_size * h1) * dtype_bytes
    # per-layer KV on ONE device (paper Eq. 18; kv heads, not H1, for GQA)
    m_kv = 2 * dtype_bytes * batch * cfg.kv_dim * (seq_len + gen_len) / n
    # intermediate activations (paper Eq. 19)
    m_mid = 3 * dtype_bytes * batch * seq_len * h1 / n
    m_gpu = device_memory_gb * 2 ** 30

    total_kv = m_kv * L
    fits = m_w / n + m_mid + m_vocab + total_kv <= m_gpu
    if fits:
        l_gpu = L
    else:
        l_gpu = int((m_gpu - m_w / n - m_mid - m_vocab) / m_kv)
        l_gpu = max(0, min(L, l_gpu))
    return OffloadPlan(
        l_gpu=l_gpu, l_cpu=L - l_gpu,
        bytes_weights=int(m_w), bytes_kv_layer=int(m_kv),
        bytes_mid=int(m_mid), bytes_vocab=int(m_vocab),
        device_budget=int(m_gpu), needs_offload=not fits)


@dataclass(frozen=True)
class OffloadLatencyModel:
    """Analytic latency model for the Table-3 comparison.

    Calibrated to the paper's Table 3 measurements:
      * CPU_Calc 37.74 ms @ B=1, S=256K, H1=5120 -> ~140 GFLOP/s host;
      * Upload 50.81 ms for the 671 MB per-device KV slice -> ~13.2 GB/s
        EFFECTIVE PCIe (theoretical 32 GB/s; the paper itself notes
        "real-world bandwidth ... may prevent it from reaching the peak").
    """
    pcie_gbps: float = 13.2          # effective PCIe (paper-measured)
    host_gflops: float = 140.0       # sustained host attention GFLOP/s
    device_tflops: float = 197.0     # device bf16 peak

    def classical_upload_s(self, kv_bytes_layer: float) -> float:
        """Classical offloading: upload the layer's KV cache, then compute."""
        return kv_bytes_layer / (self.pcie_gbps * 1e9)

    def coop_offupload_s(self, batch: int, q_dim: int,
                         dtype_bytes: int = 2) -> float:
        """Cooperative: ship QKV (new token) down + result up -- O(B*H)."""
        qkv = 3 * batch * q_dim * dtype_bytes
        out = batch * q_dim * dtype_bytes
        return (qkv + out) / (self.pcie_gbps * 1e9)

    def host_attention_s(self, batch: int, kv_len: int, q_dim: int) -> float:
        flops = 2 * 2 * batch * kv_len * q_dim          # QK^T + PV
        return flops / (self.host_gflops * 1e9)

    def device_attention_s(self, batch: int, kv_len: int, q_dim: int) -> float:
        flops = 2 * 2 * batch * kv_len * q_dim
        # decode attention is HBM-bound; charge bytes instead of flops
        bytes_ = 2 * batch * kv_len * q_dim * 2
        return max(flops / (self.device_tflops * 1e12),
                   bytes_ / (819e9))


def kv_page_bytes(cfg: ModelConfig, page_size: int,
                  dtype_bytes: int = 2) -> int:
    """Bytes of ONE KV page across all layers (K+V) -- the unit the
    page-pressure subsystem moves over PCIe when it swaps a preempted
    sequence's pages to the host pool."""
    return 2 * dtype_bytes * cfg.num_layers * cfg.kv_dim * page_size


def preempt_cost_model(cfg: ModelConfig, *, n_pages: int, n_tokens: int,
                       page_size: int,
                       model: OffloadLatencyModel = OffloadLatencyModel(),
                       dtype_bytes: int = 2,
                       swap_latency_s: float = 5e-4):
    """(swap_s, recompute_s) for evicting a sequence with ``n_pages``
    materialised pages covering ``n_tokens`` tokens.

    Swap is a PCIe round trip (device->host now, host->device on resume)
    at the paper-measured effective bandwidth plus a fixed per-transfer
    latency, so small victims favour recompute; recompute charges the
    full re-prefill FLOPs (~2 * params per token) at device peak, so
    long-context victims favour swap.  The crossover is where the
    ``preempt_policy="auto"`` victim policy flips.
    """
    from repro.analysis.flops import param_count
    bytes_ = n_pages * kv_page_bytes(cfg, page_size, dtype_bytes)
    swap_s = 2 * (swap_latency_s + bytes_ / (model.pcie_gbps * 1e9))
    recompute_s = (2 * param_count(cfg) * n_tokens
                   / (model.device_tflops * 1e12))
    return swap_s, recompute_s


def table3_row(cfg: ModelConfig, seq_len: int, *, batch: int = 1,
               n_devices: int = 8,
               model: OffloadLatencyModel = OffloadLatencyModel(),
               device_memory_gb: float = 16.0):
    """One row of the paper's Table 3 (per-layer attention latency)."""
    plan = plan_offload(cfg, batch=batch, seq_len=seq_len, gen_len=64,
                        n_devices=n_devices,
                        device_memory_gb=device_memory_gb)
    kv_dim = cfg.kv_dim
    gpu_calc = model.device_attention_s(batch, seq_len, cfg.q_dim)
    if not plan.needs_offload:
        return dict(seq=seq_len, offload=False, gpu_calc_s=gpu_calc,
                    classical_total_s=gpu_calc, coop_total_s=gpu_calc,
                    l_cpu=0, l_gpu=plan.l_gpu)
    upload = model.classical_upload_s(plan.bytes_kv_layer)
    cpu_calc = model.host_attention_s(batch, seq_len, cfg.q_dim)
    off_up = model.coop_offupload_s(batch, cfg.q_dim)
    return dict(seq=seq_len, offload=True,
                classical_upload_s=upload,
                gpu_calc_s=gpu_calc,
                classical_total_s=upload + gpu_calc,
                coop_cpu_calc_s=cpu_calc,
                coop_offupload_s=off_up,
                coop_total_s=cpu_calc + off_up,
                speedup=(upload + gpu_calc) / (cpu_calc + off_up),
                l_cpu=plan.l_cpu, l_gpu=plan.l_gpu)


def max_context_length(cfg: ModelConfig, *, batch: int, n_devices: int,
                       device_memory_gb: float, host_memory_gb: float,
                       dtype_bytes: int = 2, gen_len: int = 64) -> dict:
    """Max supported S without vs with the cooperative strategy (the
    paper's 16K -> 256K headline on 8xV100)."""
    def fits_device_only(s):
        p = plan_offload(cfg, batch=batch, seq_len=s, gen_len=gen_len,
                         n_devices=n_devices,
                         device_memory_gb=device_memory_gb,
                         dtype_bytes=dtype_bytes)
        return not p.needs_offload

    def fits_coop(s):
        p = plan_offload(cfg, batch=batch, seq_len=s, gen_len=gen_len,
                         n_devices=n_devices,
                         device_memory_gb=device_memory_gb,
                         dtype_bytes=dtype_bytes)
        host_kv = p.bytes_kv_layer * p.l_cpu * n_devices
        return (p.l_gpu >= 0 and
                host_kv <= host_memory_gb * 2 ** 30)

    def bisect(pred, lo=1024, hi=1 << 24):
        if not pred(lo):
            return 0
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if pred(mid):
                lo = mid
            else:
                hi = mid - 1
        return lo

    return dict(device_only=bisect(fits_device_only),
                cooperative=bisect(fits_coop))


# ---------------------------------------------------------------------------
# Execution engine: host-resident KV + host attention
# ---------------------------------------------------------------------------

class HostOffloadEngine:
    """Runtime for T4.  Layers < l_cpu keep KV on the host and compute
    decode attention there; the rest stay on device.

    On this container host == device == CPU backend, so the data path is
    exercised end-to-end while transfer latencies come from the analytic
    model.  On a real TPU pod, `host_device` is the colocated CPU backend
    and `device_put` crosses PCIe.
    """

    def __init__(self, cfg: ModelConfig, plan: OffloadPlan, *,
                 max_batch: int, max_seq: int,
                 host_device: Optional[jax.Device] = None):
        self.cfg = cfg
        self.plan = plan
        self.host = host_device or jax.devices("cpu")[0]
        kvshape = (max_batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        self._host_kv = {
            li: (jnp.zeros(kvshape, jnp.float32),
                 jnp.zeros(kvshape, jnp.float32))
            for li in range(plan.l_cpu)
        }
        self._host_attn = jax.jit(self._attn, device=self.host)

    @staticmethod
    def _attn(q, k, v, kv_len):
        from repro.kernels.fastattn.ref import decode_reference
        return decode_reference(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), kv_len).transpose(0, 2, 1, 3)

    def is_host_layer(self, layer_idx: int) -> bool:
        return layer_idx < self.plan.l_cpu

    def prefill_offload(self, layer_idx: int, k: jax.Array, v: jax.Array):
        """Async KV offload after the prefill KV projection (paper step 3)."""
        if not self.is_host_layer(layer_idx):
            return
        k_h = jax.device_put(k, self.host)
        v_h = jax.device_put(v, self.host)
        b, s = k.shape[0], k.shape[1]
        kh, vh = self._host_kv[layer_idx]
        kh = jax.lax.dynamic_update_slice(kh, k_h.astype(kh.dtype),
                                          (0, 0, 0, 0))
        vh = jax.lax.dynamic_update_slice(vh, v_h.astype(vh.dtype),
                                          (0, 0, 0, 0))
        self._host_kv[layer_idx] = (kh, vh)

    def decode_append(self, layer_idx: int, k_new, v_new, pos: int):
        kh, vh = self._host_kv[layer_idx]
        k_h = jax.device_put(k_new, self.host).astype(kh.dtype)
        v_h = jax.device_put(v_new, self.host).astype(vh.dtype)
        kh = jax.lax.dynamic_update_slice(kh, k_h, (0, pos, 0, 0))
        vh = jax.lax.dynamic_update_slice(vh, v_h, (0, pos, 0, 0))
        self._host_kv[layer_idx] = (kh, vh)

    def decode_attention(self, layer_idx: int, q, kv_len):
        """Offload Q, compute attention on host, upload the result
        (paper step 4: 'uses CPUs to finish the attention calculation ...
        results will be uploaded to GPUs')."""
        kh, vh = self._host_kv[layer_idx]
        q_h = jax.device_put(q, self.host)
        out = self._host_attn(q_h, kh, vh,
                              jnp.asarray(kv_len, jnp.int32))
        return jax.device_put(out, q.devices().pop())
