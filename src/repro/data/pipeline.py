"""Token data pipeline: deterministic synthetic stream or memmapped file,
sharded per host, with background prefetch.

Synthetic mode generates a fixed-seed Zipf-ish token stream so loss curves
are reproducible across restarts (the pipeline state -- stream position --
is part of the checkpoint extras, giving exact resume).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    host_count: int = 1
    host_index: int = 0
    seed: int = 1234
    path: Optional[str] = None       # memmap .bin (uint16/uint32) if set
    prefetch: int = 2


class TokenPipeline:
    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        assert cfg.global_batch % cfg.host_count == 0
        self.local_batch = cfg.global_batch // cfg.host_count
        self.step = start_step
        self._mm = None
        if cfg.path:
            self._mm = np.memmap(cfg.path, dtype=np.uint16, mode="r")

    # -- deterministic access ------------------------------------------
    def _batch_at(self, step: int) -> np.ndarray:
        cfg = self.cfg
        if self._mm is not None:
            n_tok = cfg.seq_len + 1
            total = self.local_batch * n_tok
            start = ((step * cfg.global_batch + self.cfg.host_index
                      * self.local_batch) * n_tok) % (len(self._mm) - total)
            flat = np.asarray(self._mm[start:start + total])
            return flat.reshape(self.local_batch, n_tok).astype(np.int32)
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index))
        # zipf-ish distribution clipped to vocab
        z = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
        return (z % cfg.vocab_size).astype(np.int32)

    def next(self) -> dict:
        arr = self._batch_at(self.step)
        self.step += 1
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])

    # -- prefetching iterator -------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def worker():
            while not stop.is_set():
                try:
                    q.put(self.next(), timeout=0.5)
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
