"""grok-1-314b [moe] -- 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
Experts (8) do not divide the model axis (16), so expert FFNs are
tensor-sharded along d_ff instead of expert-parallel (see sharding/rules).
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        block_pattern=("moe",),
        num_experts=8,
        num_experts_per_tok=2,
        attn_logit_softcap=30.0,    # grok uses attn logit softcapping
        mlp_type="geglu",           # 3-matrix gated expert MLP (-> ~314B total)
        norm_type="rmsnorm",
        tie_embeddings=True,
    )


register("grok-1-314b", config)
