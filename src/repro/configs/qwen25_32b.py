"""qwen2.5-32b [dense] -- GQA, QKV bias [hf:Qwen/Qwen2.5-*].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        block_pattern=("attn",),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=False,
    )


register("qwen2.5-32b", config)
