"""hymba-1.5b [hybrid] -- parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each block runs attention heads and mamba heads in parallel on the same
input and fuses their (normalized) outputs.  Most layers use sliding-window
attention; every 8th layer is global (per the Hymba paper's 3-global-layer
design scaled to 32L).
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    pattern = ["hymba_local"] * 32
    for i in (0, 15, 31):           # first / middle / last layers global
        pattern[i] = "hymba"
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        block_pattern=tuple(pattern),
        window_size=1024,
        ssm_state_size=16,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=True,
    )


register("hymba-1.5b", config)
