"""xlstm-125m [ssm] -- sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks carry
their own up/down projections (proj factor 2) instead of a separate FFN.
Block pattern follows the paper's mostly-mLSTM ratio: one sLSTM block per
six layers (layers 2 and 8 here).
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    pattern = ["mlstm"] * 12
    pattern[2] = "slstm"
    pattern[8] = "slstm"
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        block_pattern=tuple(pattern),
        rope_type="none",
        norm_type="layernorm",
        mlp_type="gelu",
        tie_embeddings=True,
    )


register("xlstm-125m", config)
