"""gemma2-2b [dense] -- local+global alternating, logit softcap [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.  Sliding window 4096
on local layers; attn softcap 50, final softcap 30; GeGLU; post-block norms.
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        block_pattern=("attn_local", "attn"),
        window_size=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        mlp_type="geglu",
        norm_type="rmsnorm",
        post_norm=True,
        embed_scale=True,
        tie_embeddings=True,
    )


register("gemma2-2b", config)
