"""stablelm-3b [dense] [hf:stabilityai/stablelm-*].

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.  LayerNorm + SwiGLU,
partial-rotary in the HF model; we use full rotary (head_dim=80).
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=50304,
        block_pattern=("attn",),
        mlp_type="swiglu",
        norm_type="layernorm",
        tie_embeddings=False,
    )


register("stablelm-3b", config)
