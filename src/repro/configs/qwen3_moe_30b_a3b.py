"""qwen3-moe-30b-a3b [moe] -- 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff=768(per-expert) vocab=151936.
128 experts divide the model axis (16) -> expert parallelism.
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        block_pattern=("moe",),
        num_experts=128,
        num_experts_per_tok=8,
        moe_dff=768,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


register("qwen3-moe-30b-a3b", config)
