"""qwen2-vl-72b [vlm] -- M-RoPE, dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  The transformer
backbone only: the vision frontend is a stub -- input_specs() feeds
precomputed patch embeddings alongside token embeddings, and positions are
3-component (temporal/height/width) for M-RoPE.
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        block_pattern=("attn",),
        qkv_bias=True,
        rope_type="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        modality="vision_stub",
        tie_embeddings=False,
    )


register("qwen2-vl-72b", config)
