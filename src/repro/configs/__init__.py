"""Built-in architecture configs (assigned pool + the paper's own models).

Each module defines ``config() -> ModelConfig`` and registers itself.
"""
from repro.configs import (  # noqa: F401
    xlstm_125m,
    qwen25_32b,
    phi3_mini_3p8b,
    gemma2_2b,
    stablelm_3b,
    grok1_314b,
    qwen3_moe_30b_a3b,
    hymba_1p5b,
    qwen2_vl_72b,
    whisper_small,
    paper_models,
)
from repro.config import SHAPES  # noqa: F401

# Canonical id -> module-registered name mapping (ids use dashes).
ASSIGNED_ARCHS = (
    "xlstm-125m",
    "qwen2.5-32b",
    "phi3-mini-3.8b",
    "gemma2-2b",
    "stablelm-3b",
    "grok-1-314b",
    "qwen3-moe-30b-a3b",
    "hymba-1.5b",
    "qwen2-vl-72b",
    "whisper-small",
)
