"""whisper-small [audio] -- enc-dec, conv frontend stub [arXiv:2212.04356].

12L d_model=768 12H d_ff=3072 vocab=51865.  Encoder (12L, bidirectional)
consumes precomputed frame embeddings (conv stub); decoder (12L) has causal
self-attention + cross-attention over encoder states.
"""
from repro.config import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,              # decoder layers
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        block_pattern=("attn",),
        is_encoder_decoder=True,
        encoder_layers=12,
        encoder_seq=1500,
        rope_type="none",           # whisper uses learned/sinusoidal pos
        norm_type="layernorm",
        mlp_type="gelu",
        modality="audio_stub",
        tie_embeddings=True,
    )


register("whisper-small", config)
