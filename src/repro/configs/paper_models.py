"""The paper's own evaluation models (Table 1) as configs.

PanGu-38B / PanGu-71B / LLaMA2-7B / LLaMA2-70B / LLaMA-65B / OPT-30B.
Used by the benchmark harness to reproduce the paper's tables at the
operator level and (scaled-down) end to end.
"""
from repro.config import ModelConfig, register


def pangu_38b() -> ModelConfig:
    return ModelConfig(
        name="pangu-38b", family="dense", num_layers=40, d_model=5120,
        num_heads=40, num_kv_heads=40, head_dim=128, d_ff=20480,
        vocab_size=100000, mlp_type="gelu", norm_type="layernorm",
        tie_embeddings=False,
    )


def pangu_71b() -> ModelConfig:
    return ModelConfig(
        name="pangu-71b", family="dense", num_layers=64, d_model=6144,
        num_heads=48, num_kv_heads=48, head_dim=128, d_ff=24576,
        vocab_size=100000, mlp_type="gelu", norm_type="layernorm",
        tie_embeddings=False,
    )


def llama2_7b() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
        vocab_size=32000, mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=False,
    )


def llama2_70b() -> ModelConfig:
    return ModelConfig(
        name="llama2-70b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, head_dim=128, d_ff=28672,
        vocab_size=32000, mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=False,
    )


def llama_65b() -> ModelConfig:
    return ModelConfig(
        name="llama-65b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=64, head_dim=128, d_ff=22016,
        vocab_size=32000, mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=False,
    )


def opt_30b() -> ModelConfig:
    return ModelConfig(
        name="opt-30b", family="dense", num_layers=48, d_model=7168,
        num_heads=56, num_kv_heads=56, head_dim=128, d_ff=28672,
        vocab_size=50272, mlp_type="gelu", norm_type="layernorm",
        rope_type="none", tie_embeddings=False,
    )


for _f in (pangu_38b, pangu_71b, llama2_7b, llama2_70b, llama_65b, opt_30b):
    register(_f().name, _f)
