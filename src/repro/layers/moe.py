"""Mixture-of-experts FFN with argsort-based capacity dispatch.

Top-k routing -> per-group argsort by expert id -> static-capacity gather
-> batched expert GEMMs -> weighted scatter-combine.  O(tokens * top_k)
memory (no GShard (T, E, C) one-hot dispatch tensor), which is what lets
the 128-expert qwen3-moe cells compile at 512 devices.

Expert parallelism: the expert dim carries the `expert -> model` logical
axis; when E % model_axis != 0 (grok-1: 8 experts on a 16-way axis) the
rule drops to per-expert tensor parallelism on `ff` instead (the
divisibility check in sharding.rules handles this automatically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import common
from repro.sharding.rules import constrain


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.expert_dff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "router": common.dense_init(ks[0], d, e, dtype),
        "w_up": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32)
                       * scale).astype(dtype)
    return p


def moe_logical(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.expert_dff, cfg.num_experts
    p = {
        "router": (("d_model", None), (d, e)),
        "w_up": (("expert", "d_model", "ff"), (e, d, f)),
        "w_down": (("expert", "ff", "d_model"), (e, f, d)),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (("expert", "d_model", "ff"), (e, d, f))
    return p


def apply_moe(params, x, cfg: ModelConfig, *,
              capacity_factor: float = None):
    """x: (B, S, D) -> (B, S, D).  Groups = batch rows (dispatch is local
    to a group, so group boundaries align with the data sharding)."""
    b, s, d = x.shape
    e = cfg.num_experts
    k = cfg.num_experts_per_tok
    cf = capacity_factor or cfg.moe_capacity_factor
    cap = max(1, int(s * k / e * cf))

    logits = common.dense(x, params["router"]).astype(jnp.float32)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # ---- per-group (batch row) dispatch ------------------------------
    # flatten the k assignments of the s tokens: (B, S*k)
    flat_expert = idx.reshape(b, s * k)
    order = jnp.argsort(flat_expert, axis=-1)               # stable
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    # position of each sorted entry within its expert's run
    same = sorted_expert[:, :, None] == jnp.arange(e)[None, None, :]
    pos_in_e = jnp.cumsum(same, axis=1) - 1
    slot = jnp.take_along_axis(
        pos_in_e.reshape(b, s * k, e), sorted_expert[..., None],
        axis=-1)[..., 0]                                    # (B, S*k)
    keep = slot < cap
    # destination (expert, slot) for each sorted assignment
    dest = jnp.where(keep, sorted_expert * cap + slot, e * cap)
    token_of = order // k                                   # (B, S*k)

    # gather tokens into (B, E, cap, D).  The index tensor is constrained
    # to the expert sharding BEFORE the gather so every `model` shard
    # gathers only its own experts' rows from the (replicated-D) tokens --
    # otherwise GSPMD materializes the full dispatched tensor and
    # all-reduces it (measured: 4.3 GB x layers of avoidable all-reduce).
    inv = jnp.full((b, e * cap + 1), s, jnp.int32)          # s = dummy row
    inv = jax.vmap(lambda inv_b, dest_b, tok_b:
                   inv_b.at[dest_b].set(tok_b))(inv, dest, token_of)
    inv = inv[:, :e * cap].reshape(b, e, cap)
    inv = constrain(inv, "batch", "expert", None)
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad[:, None], inv[..., None], axis=2)             # (B, E, cap, D)
    xe = constrain(xe, "batch", "expert", None, None)

    # ---- expert FFN ----------------------------------------------------
    h = jnp.einsum("becd,edf->becf", xe, params["w_up"].astype(x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("becd,edf->becf", xe,
                       params["w_gate"].astype(x.dtype))
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(x.dtype))
    ye = constrain(ye, "batch", "expert", None, None)
    ye = ye.reshape(b, e * cap, d)

    # ---- combine: per-shard scatter-add + small partial reduction -----
    # A gather from the expert-sharded ye would make GSPMD all-reduce the
    # full (B, S*k, D) picked tensor (4.3 GB/layer for qwen3-moe).
    # Instead each expert shard scatter-adds its own slots' weighted
    # outputs into a (B, S+1, D) partial; the cross-shard reduction is
    # then only (B, S, D) -- k*drop-factor smaller.
    gate_flat = jnp.take_along_axis(
        gates.reshape(b, s * k), order, axis=-1)            # sorted order
    slot_gate = jnp.zeros((b, e * cap + 1), jnp.float32)
    slot_gate = jax.vmap(lambda gb, db, vb: gb.at[db].set(vb))(
        slot_gate, dest, gate_flat)
    slot_gate = slot_gate[:, :e * cap].reshape(b, e, cap)
    slot_gate = constrain(slot_gate, "batch", "expert", None)
    weighted = ye.reshape(b, e, cap, d) * \
        slot_gate[..., None].astype(ye.dtype)
    weighted = constrain(weighted, "batch", "expert", None, None)
    y_pad = jnp.zeros((b, s + 1, d), x.dtype)
    # dropped slots carry dummy token index s -> land on the padding row
    y_pad = jax.vmap(lambda yb, tb, wb: yb.at[tb].add(wb))(
        y_pad, inv.reshape(b, e * cap), weighted.reshape(b, e * cap, d))
    return constrain(y_pad[:, :s], "batch", None, None)
