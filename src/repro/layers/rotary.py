"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim/2 frequency channels into (temporal, height,
width) sections; positions are (3, B, S) -- text tokens use t=h=w=index,
vision patch tokens use their 3-D coordinates (the frontend stub supplies
them precomputed).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def _rotate(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, *,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                             # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, *,
                sections: Sequence[int], theta: float = 10_000.0):
    """x: (B, S, H, D); positions3: (3, B, S) int (t, h, w)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                             # (D/2,)
    # section s of the frequency channels uses position component s
    comp = jnp.concatenate([
        jnp.full((sec,), i, jnp.int32) for i, sec in enumerate(sections)])
    pos = jnp.take(positions3, comp, axis=0)                 # (D/2, B, S)
    angles = pos.transpose(1, 2, 0).astype(jnp.float32) * freqs
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)
