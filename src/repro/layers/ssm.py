"""Recurrent layers: Mamba (selective scan), xLSTM mLSTM / sLSTM blocks.

Distribution: recurrences run with the sequence dim UNSHARDED (scans are
sequential); instead the channel/value dims carry the `channels -> model`
logical axis -- mamba channels are independent (diagonal A) and the mLSTM
value dim is a free axis of every einsum, so channel sharding costs zero
collectives inside the scan (DESIGN.md §4.1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import common
from repro.sharding.rules import constrain


# ===========================================================================
# Mamba (S6) -- used by the hymba parallel-head block
# ===========================================================================

class MambaState(NamedTuple):
    h: jax.Array            # (B, DI, N) ssm state
    conv: jax.Array         # (B, K-1, DI) rolling conv inputs


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.q_dim                       # mirror attention heads (hymba)
    n = cfg.ssm_state_size
    kconv = cfg.conv_kernel
    ks = jax.random.split(key, 6)
    return {
        "w_in": common.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (kconv, di), jnp.float32)
                   * kconv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bc": common.dense_init(ks[2], di, 2 * n, dtype),
        "w_dt": common.dense_init(ks[3], di, di, dtype, scale=di ** -0.5),
        "dt_bias": jnp.full((di,), -4.0, dtype),
        "a_log": jnp.zeros((di, n), jnp.float32) +
        jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, :],
        "d_skip": jnp.ones((di,), dtype),
        "w_out": common.dense_init(ks[4], di, d, dtype, scale=di ** -0.5),
    }


def mamba_logical(cfg: ModelConfig):
    d, di, n, kconv = (cfg.d_model, cfg.q_dim, cfg.ssm_state_size,
                       cfg.conv_kernel)
    return {
        "w_in": (("d_model", "channels"), (d, 2 * di)),
        "conv_w": ((None, "channels"), (kconv, di)),
        "conv_b": (("channels",), (di,)),
        "w_bc": (("channels", None), (di, 2 * n)),
        "w_dt": (("channels", None), (di, di)),
        "dt_bias": (("channels",), (di,)),
        "a_log": (("channels", None), (di, n)),
        "d_skip": (("channels",), (di,)),
        "w_out": (("channels", "d_model"), (di, d)),
    }


def _mamba_scan_chunk(h0, xc, dtc, bc, cc, a):
    """Associative scan within one chunk.

    xc: (B, L, DI); dtc: (B, L, DI); bc/cc: (B, L, N); a: (DI, N).
    h' = exp(dt*A) h + dt * B * x ;  y = (h C) + skip.
    """
    decay = jnp.exp(dtc[..., None] * a)                     # (B,L,DI,N)
    drive = (dtc * xc)[..., None] * bc[:, :, None, :]       # (B,L,DI,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    dec, drv = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    h = dec * h0[:, None] + drv                             # (B,L,DI,N)
    y = jnp.einsum("bldn,bln->bld", h, cc)
    return y, h[:, -1]


def apply_mamba(params, x, cfg: ModelConfig, *, chunk: int = 256,
                state: Optional[MambaState] = None, decode: bool = False):
    """x: (B, S, D) -> (B, S, D) (+ state when decode)."""
    b, s, d = x.shape
    di, n = cfg.q_dim, cfg.ssm_state_size
    kconv = cfg.conv_kernel
    xz = common.dense(x, params["w_in"])
    xin, z = jnp.split(xz, 2, axis=-1)                      # (B,S,DI)
    xin = constrain(xin, "batch", None, "channels")

    if state is None:
        conv_hist = jnp.zeros((b, kconv - 1, di), xin.dtype)
        h0 = jnp.zeros((b, di, n), jnp.float32)
    else:
        conv_hist, h0 = state.conv, state.h

    # causal depthwise conv over [hist | xin]
    xin_ext = jnp.concatenate([conv_hist, xin], axis=1)
    conv_w = params["conv_w"].astype(xin.dtype)             # (K, DI)
    xc = sum(xin_ext[:, i:i + s] * conv_w[i] for i in range(kconv))
    xc = jax.nn.silu(xc + params["conv_b"].astype(xin.dtype))
    new_hist = xin_ext[:, s:]

    dt = jax.nn.softplus(common.dense(xc, params["w_dt"])
                         + params["dt_bias"].astype(xc.dtype))
    bc_cc = common.dense(xc, params["w_bc"])
    bmat, cmat = jnp.split(bc_cc.astype(jnp.float32), 2, axis=-1)
    a = -jnp.exp(params["a_log"])                           # (DI, N)
    xcf = xc.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    if decode or s == 1:
        y, h = _mamba_scan_chunk(h0, xcf, dtf, bmat, cmat, a)
    else:
        chunk = min(chunk, s)
        pad = (-s) % chunk
        if pad:
            xcf = jnp.pad(xcf, ((0, 0), (0, pad), (0, 0)))
            dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
            bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
            cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        nc = (s + pad) // chunk

        def step(h, xs):
            xj, dj, bj, cj = xs
            y, h = _mamba_scan_chunk(h, xj, dj, bj, cj, a)
            return h, y

        reshape = lambda t: t.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
        h, ys = jax.lax.scan(
            step, h0, (reshape(xcf), reshape(dtf), reshape(bmat),
                       reshape(cmat)))
        y = ys.transpose(1, 0, 2, 3).reshape(b, s + pad, di)[:, :s]

    y = y.astype(x.dtype) + xc * params["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", None, "channels")
    out = common.dense(y, params["w_out"])
    if decode:
        return out, MambaState(h=h, conv=new_hist)
    return out


# ===========================================================================
# xLSTM mLSTM block
# ===========================================================================

class MLSTMState(NamedTuple):
    c: jax.Array            # (B, H, dk, dv)
    n: jax.Array            # (B, H, dk)
    m: jax.Array            # (B, H)
    conv: jax.Array         # placeholder for API symmetry


def _di(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.mlstm_proj_factor)


def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = _di(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": common.dense_init(ks[0], d, di, dtype),
        "w_gate": common.dense_init(ks[1], d, di, dtype),
        "wq": common.dense_init(ks[2], di, di, dtype),
        "wk": common.dense_init(ks[3], di, di, dtype),
        "wv": common.dense_init(ks[4], di, di, dtype),
        "w_if": common.dense_init(ks[5], di, 2 * cfg.num_heads, dtype),
        "b_if": jnp.concatenate([jnp.zeros((cfg.num_heads,), jnp.float32),
                                 jnp.full((cfg.num_heads,), 3.0)]
                                ).astype(dtype),
        "w_down": common.dense_init(ks[6], di, d, dtype, scale=di ** -0.5),
    }


def mlstm_logical(cfg: ModelConfig):
    d, di, h = cfg.d_model, _di(cfg), cfg.num_heads
    return {
        "w_up": (("d_model", "channels"), (d, di)),
        "w_gate": (("d_model", "channels"), (d, di)),
        "wq": ((None, "channels"), (di, di)),
        "wk": ((None, "channels"), (di, di)),
        "wv": ((None, "channels"), (di, di)),
        "w_if": (("channels", None), (di, 2 * h)),
        "b_if": ((None,), (2 * h,)),
        "w_down": (("channels", "d_model"), (di, d)),
    }


def apply_mlstm(params, x, cfg: ModelConfig, *, chunk: int = 128,
                state: Optional[MLSTMState] = None, decode: bool = False,
                impl: str = "reference"):
    """xLSTM mLSTM block body (norm handled by the caller)."""
    from repro.kernels.mlstm import ref as mref
    from repro.kernels.mlstm.ops import mlstm_chunkwise
    b, s, d = x.shape
    di = _di(cfg)
    nh = cfg.num_heads
    hd = di // nh
    xin = common.dense(x, params["w_up"])
    z = common.dense(x, params["w_gate"])
    q = common.dense(xin, params["wq"]).reshape(b, s, nh, hd)
    k = common.dense(xin, params["wk"]).reshape(b, s, nh, hd)
    v = common.dense(xin, params["wv"]).reshape(b, s, nh, hd)
    gif = (common.dense(xin, params["w_if"])
           + params["b_if"].astype(x.dtype)).astype(jnp.float32)
    ig, fg = jnp.split(gif, 2, axis=-1)                      # (B,S,H)
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = constrain(v.transpose(0, 2, 1, 3), "batch", "heads", None,
                   "channels")
    igT = ig.transpose(0, 2, 1)
    fgT = fg.transpose(0, 2, 1)

    if decode:
        init = None if state is None else (state.c, state.n, state.m)
        h_out, st = mref.mlstm_recurrent(qT, kT, vT, igT, fgT,
                                         initial_state=init)
        new_state = MLSTMState(c=st[0], n=st[1], m=st[2],
                               conv=jnp.zeros((0,), x.dtype))
    else:
        if impl in ("pallas", "interpret"):
            h_out = mlstm_chunkwise(qT, kT, vT, igT, fgT, chunk, impl)
        else:
            h_out = mref.mlstm_chunkwise(qT, kT, vT, igT, fgT, chunk=chunk)
        new_state = None
    h_out = h_out.transpose(0, 2, 1, 3).reshape(b, s, di).astype(x.dtype)
    out = common.dense(h_out * jax.nn.silu(z), params["w_down"])
    if decode:
        return out, new_state
    return out


# ===========================================================================
# xLSTM sLSTM block (inherently sequential: recurrent gate connections)
# ===========================================================================

class SLSTMState(NamedTuple):
    c: jax.Array            # (B, DI)
    n: jax.Array
    h: jax.Array
    m: jax.Array


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = _di(cfg)
    nh = cfg.num_heads
    hd = di // nh
    ks = jax.random.split(key, 4)
    return {
        "w_up": common.dense_init(ks[0], d, di, dtype),
        "w_gates": common.dense_init(ks[1], di, 4 * di, dtype),
        # block-diagonal recurrent weights, one (hd, hd) block per head
        "r_gates": (jax.random.normal(ks[2], (4, nh, hd, hd), jnp.float32)
                    * hd ** -0.5).astype(dtype),
        "b_gates": jnp.zeros((4 * di,), dtype),
        "w_down": common.dense_init(ks[3], di, d, dtype, scale=di ** -0.5),
    }


def slstm_logical(cfg: ModelConfig):
    d, di, nh = cfg.d_model, _di(cfg), cfg.num_heads
    hd = di // nh
    return {
        "w_up": (("d_model", "channels"), (d, di)),
        "w_gates": (("channels", None), (di, 4 * di)),
        "r_gates": ((None, "heads", None, None), (4, nh, hd, hd)),
        "b_gates": ((None,), (4 * di,)),
        "w_down": (("channels", "d_model"), (di, d)),
    }


def apply_slstm(params, x, cfg: ModelConfig, *,
                state: Optional[SLSTMState] = None, decode: bool = False):
    b, s, d = x.shape
    di = _di(cfg)
    nh = cfg.num_heads
    hd = di // nh
    xin = common.dense(x, params["w_up"])
    pre = (common.dense(xin, params["w_gates"])
           + params["b_gates"].astype(x.dtype)).astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, di), jnp.float32)
        n0 = jnp.zeros((b, di), jnp.float32)
        h0 = jnp.zeros((b, di), jnp.float32)
        m0 = jnp.full((b, di), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state

    r = params["r_gates"].astype(jnp.float32)                # (4,NH,hd,hd)

    def step(carry, pre_t):
        c, n, h, m = carry
        hh = h.reshape(b, nh, hd)
        rec = jnp.einsum("bnd,gnde->bgne", hh, r).reshape(b, 4, di)
        zi, ii, fi, oi = [pre_t[:, i * di:(i + 1) * di] + rec[:, i]
                          for i in range(4)]
        z = jnp.tanh(zi)
        o = jax.nn.sigmoid(oi)
        logf = -jax.nn.softplus(-fi)                         # log sigmoid(f)
        m_new = jnp.maximum(logf + m, ii)
        i_p = jnp.exp(ii - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        h_new = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0),
                                    pre.transpose(1, 0, 2))
    out = common.dense(hs.transpose(1, 0, 2).astype(x.dtype),
                       params["w_down"])
    if decode:
        return out, SLSTMState(c=c, n=n, h=h, m=m)
    return out
