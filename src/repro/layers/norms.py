"""RMSNorm / LayerNorm (f32 statistics, cast back to activation dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def init_norm(d: int, norm_type: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_logical(d: int, norm_type: str):
    p = {"scale": (("d_model",), (d,))}
    if norm_type == "layernorm":
        p["bias"] = (("d_model",), (d,))
    return p


def apply_norm(params, x, norm_type: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * (var + eps) ** -0.5 * params["scale"].astype(jnp.float32)
        return y.astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + \
        params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
