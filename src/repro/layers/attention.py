"""GQA attention layer wired to the FastAttention core.

Distribution (DESIGN.md §4.1): activations are sequence-sharded on the
`model` axis (context parallelism).  Q keeps its seq sharding; K/V are
constrained replicated along `model` (one small GQA KV all-gather per
layer), so the flash scan partitions cleanly over Q rows with zero extra
collectives.  At decode time the KV cache is instead sharded along its
*sequence* dim (`kv_seq -> model`); XLA decomposes the softmax/PV
reductions over the sharded dim into exactly the LSE-merge collectives of
core/distributed_decode.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core.compat import shard_map as _shard_map
from repro.core.distributed_decode import (attend_with_positions,
                                           merge_partial_attention,
                                           paged_local_view,
                                           paged_shard_kv_positions)
from repro.core.fastattention import (default_paged_impl, fast_attention,
                                      fast_attention_decode,
                                      fast_attention_prefill_paged)
from repro.core.tiled_allreduce import matmul_allreduce
from repro.layers import common, rotary
from repro.sharding.rules import constrain
from repro.sharding.tp import current_tp

# Decode KV-cache layout: "bshd" (token-major, default) or "bhsd"
# (head-major: the QK/PV contractions need no transposed copy of the
# cache -- decode hillclimb iteration, EXPERIMENTS.md §Perf cell 3).
KV_CACHE_LAYOUT = "bshd"


class KVCache(NamedTuple):
    k: jax.Array            # (B, S_max, Hkv, D) or (B, Hkv, S_max, D)
    v: jax.Array


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d, qd, dtype),
        "wk": common.dense_init(ks[1], d, kvd, dtype),
        "wv": common.dense_init(ks[2], d, kvd, dtype),
        "wo": common.dense_init(ks[3], qd, d, dtype, scale=qd ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def attention_logical(cfg: ModelConfig):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": (("attn_row", "heads"), (d, qd)),
        "wk": (("attn_row", "heads"), (d, kvd)),
        "wv": (("attn_row", "heads"), (d, kvd)),
        "wo": (("attn_row", "d_model"), (qd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = (("heads",), (qd,))
        p["bk"] = (("heads",), (kvd,))
        p["bv"] = (("heads",), (kvd,))
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions):
    # head counts come from the weight shapes (-1), not the config: a
    # tensor-parallel shard passes its column-sliced projections through
    # the same code path (rope is per-head, independent of the count)
    b, s, _ = x.shape
    q = common.dense(x, params["wq"], params.get("bq"))
    k = common.dense(x, params["wk"], params.get("bk"))
    v = common.dense(x, params["wv"], params.get("bv"))
    q = q.reshape(b, s, -1, cfg.head_dim)
    k = k.reshape(b, s, -1, cfg.head_dim)
    v = v.reshape(b, s, -1, cfg.head_dim)
    if cfg.rope_type == "rope":
        q = rotary.apply_rope(q, positions, theta=cfg.rope_theta)
        k = rotary.apply_rope(k, positions, theta=cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = rotary.apply_mrope(q, positions, sections=cfg.mrope_sections,
                               theta=cfg.rope_theta)
        k = rotary.apply_mrope(k, positions, sections=cfg.mrope_sections,
                               theta=cfg.rope_theta)
    return q, k, v


def apply_attention(params, x, cfg: ModelConfig, *,
                    positions, window: Optional[int] = None,
                    causal: bool = True,
                    impl: Optional[str] = None) -> jax.Array:
    """Training/prefill attention.  x: (B, S, D) seq-sharded."""
    impl = impl or cfg.attention_impl
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = constrain(q, "batch", "seq", "heads", None)
    # context parallelism: KV replicated along `model` (GQA keeps it small)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    out = fast_attention(q, k, v, causal=causal, window=window,
                         softcap=cfg.attn_logit_softcap, impl=impl)
    out = constrain(out, "batch", "seq", "heads", None)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.q_dim)
    return common.dense(out, params["wo"])


def apply_cross_attention(params, x, enc_k, enc_v, cfg: ModelConfig, *,
                          impl: Optional[str] = None) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V."""
    impl = impl or cfg.attention_impl
    b, s, _ = x.shape
    q = common.dense(x, params["wq"], params.get("bq"))
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    q = constrain(q, "batch", "seq", "heads", None)
    out = fast_attention(q, enc_k, enc_v, causal=False,
                         softcap=cfg.attn_logit_softcap, impl=impl)
    out = out.reshape(b, s, cfg.q_dim)
    return common.dense(out, params["wo"])


def project_cross_kv(params, enc_states, cfg: ModelConfig):
    b, s, _ = enc_states.shape
    k = common.dense(enc_states, params["wk"], params.get("bk"))
    v = common.dense(enc_states, params["wv"], params.get("bv"))
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return constrain(k, "batch", None, "heads", None), \
        constrain(v, "batch", None, "heads", None)


def apply_attention_decode(params, x, cfg: ModelConfig, cache: KVCache, *,
                           pos, window: Optional[int] = None,
                           impl: Optional[str] = None):
    """One-token decode.  x: (B, 1, D); pos: scalar current position.

    Returns (out (B,1,D), new_cache).  The cache sequence dim carries the
    `kv_seq -> model` sharding (context-parallel decode).
    """
    impl = impl or cfg.attention_impl
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    if KV_CACHE_LAYOUT == "bhsd":
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype).transpose(0, 2, 1, 3),
            (0, 0, pos, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype).transpose(0, 2, 1, 3),
            (0, 0, pos, 0))
        k = constrain(k, "batch", "heads", "kv_seq", None)
        v = constrain(v, "batch", "heads", "kv_seq", None)
    else:
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0))
        k = constrain(k, "batch", "kv_seq", "heads", None)
        v = constrain(v, "batch", "kv_seq", "heads", None)
    kv_len = jnp.full((b,), pos + 1, jnp.int32)
    out = fast_attention_decode(
        q, k, v, kv_len, window=window, softcap=cfg.attn_logit_softcap,
        impl="reference" if impl == "reference" else impl,
        layout=KV_CACHE_LAYOUT)
    out = out.reshape(b, 1, cfg.q_dim)
    return common.dense(out, params["wo"]), KVCache(k, v)


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype) -> KVCache:
    if KV_CACHE_LAYOUT == "bhsd":
        shape = (batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
    else:
        shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# Paged decode: KV pages shared across sequences via a page table
# ---------------------------------------------------------------------------

def init_kv_pages(cfg: ModelConfig, num_pages: int, page_size: int,
                  dtype) -> KVCache:
    """Global page pools (Hkv, P, page_size, D).  Every sequence's cache
    is a subset of pages named by its page-table row; batch size does not
    appear in the storage shape -- the pool is the memory budget."""
    shape = (cfg.num_kv_heads, num_pages, page_size, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def scatter_kv_pages(cache: KVCache, k_new, v_new, page_table, positions,
                     n_valid) -> KVCache:
    """Scatter a chunk of new K/V rows into the paged pools.

    k_new/v_new: (B, S, Hkv, D); positions: (B, S) int32 global token
    positions; n_valid: (B,) int32 -- rows past it (chunk padding) are
    redirected into the scratch page so fixed-size chunks never touch
    pages owned by live sequences.  The pages covering the valid
    positions must already be materialised in ``page_table``.
    """
    hkv, npages, ps, d = cache.k.shape
    b, s = positions.shape
    page = page_table[jnp.arange(b)[:, None], positions // ps]   # (B, S)
    flat = page * ps + positions % ps
    valid = jnp.arange(s, dtype=jnp.int32)[None] < n_valid[:, None]
    flat = jnp.where(valid, flat, 0)          # padding -> scratch page 0
    # (B, S, Hkv, D) -> (Hkv, B, S, D) rows scattered at flat [b, s]
    k = cache.k.reshape(hkv, npages * ps, d).at[:, flat].set(
        k_new.astype(cache.k.dtype).transpose(2, 0, 1, 3))
    v = cache.v.reshape(hkv, npages * ps, d).at[:, flat].set(
        v_new.astype(cache.v.dtype).transpose(2, 0, 1, 3))
    return KVCache(k.reshape(hkv, npages, ps, d),
                   v.reshape(hkv, npages, ps, d))


def apply_attention_prefill_paged(params, x, cfg: ModelConfig,
                                  cache: KVCache, *, page_table, pos_start,
                                  n_valid, window: Optional[int] = None,
                                  impl: Optional[str] = None):
    """Chunked prefill against paged KV pools: one prompt chunk through
    full (not per-token) attention.

    x: (B, S_chunk, D) -- a fixed-size chunk, possibly padded past
    ``n_valid``; pos_start: (B,) int32 global position of each sequence's
    chunk start; page_table: (B, n_kv) int32.  The chunk's K/V rows are
    scattered into their pages (padding rows into scratch), then the
    chunk attends to every cached position <= its own through the page
    table.  All offsets are runtime values: one jit trace serves every
    chunk of every prompt.  Returns (out (B, S_chunk, D), new pools);
    output rows past ``n_valid`` are garbage and must be ignored.

    Under an active tensor-parallel context (sharding/tp.py) the pools
    are device-sharded and the whole layer runs as a shard_map body with
    an LSE merge -- see ``_tp_attention_prefill_paged``.
    """
    tpc = current_tp()
    if tpc is not None:
        return _tp_attention_prefill_paged(
            params, x, cfg, cache, page_table=page_table,
            pos_start=pos_start, n_valid=n_valid, window=window, tpc=tpc)
    impl = impl or default_paged_impl()
    b, s, _ = x.shape
    positions = pos_start.astype(jnp.int32)[:, None] + \
        jnp.arange(s, dtype=jnp.int32)[None]
    rope_pos = positions
    if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
        rope_pos = jnp.broadcast_to(positions, (3, b, s))
    q, k_new, v_new = _project_qkv(params, x, cfg, rope_pos)
    cache = scatter_kv_pages(cache, k_new, v_new, page_table, positions,
                             n_valid)
    kv_len = pos_start.astype(jnp.int32) + n_valid.astype(jnp.int32)
    out = fast_attention_prefill_paged(
        q, cache.k, cache.v, page_table, pos_start, kv_len,
        window=window, softcap=cfg.attn_logit_softcap, impl=impl)
    out = out.reshape(b, s, cfg.q_dim)
    return common.dense(out, params["wo"]), cache


def apply_attention_decode_paged(params, x, cfg: ModelConfig,
                                 cache: KVCache, *, page_table, pos,
                                 window: Optional[int] = None,
                                 impl: Optional[str] = None):
    """One-token decode against paged KV pools.

    x: (B, 1, D); pos: (B,) int32 per-sequence positions (ragged batch --
    unlike the dense path there is no shared scalar position);
    page_table: (B, n_kv) int32.  The new K/V row is scattered into page
    ``page_table[b, pos // page_size]`` at offset ``pos % page_size``;
    attention then reads kv_len = pos + 1 tokens through the table.
    Returns (out (B, 1, D), new KVCache of pools).

    Under an active tensor-parallel context (sharding/tp.py) the pools
    are device-sharded and the whole layer runs as a shard_map body with
    an LSE merge -- see ``_tp_attention_decode_paged``.
    """
    tpc = current_tp()
    if tpc is not None:
        return _tp_attention_decode_paged(
            params, x, cfg, cache, page_table=page_table, pos=pos,
            window=window, tpc=tpc)
    impl = impl or default_paged_impl()
    b = x.shape[0]
    positions = pos.astype(jnp.int32)[:, None]
    if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    ps = cache.k.shape[2]
    page = page_table[jnp.arange(b), pos // ps]
    off = pos % ps
    # (B, 1, Hkv, D) -> (Hkv, B, D) rows scattered at [:, page[b], off[b]]
    k = cache.k.at[:, page, off].set(
        k_new[:, 0].astype(cache.k.dtype).transpose(1, 0, 2))
    v = cache.v.at[:, page, off].set(
        v_new[:, 0].astype(cache.v.dtype).transpose(1, 0, 2))
    kv_len = pos.astype(jnp.int32) + 1
    out = fast_attention_decode(
        q, k, v, kv_len, window=window, softcap=cfg.attn_logit_softcap,
        impl=impl, page_table=page_table)
    out = out.reshape(b, 1, cfg.q_dim)
    return common.dense(out, params["wo"]), KVCache(k, v)


# ---------------------------------------------------------------------------
# Tensor-parallel paged attention (shard_map bodies over the TP mesh)
# ---------------------------------------------------------------------------
#
# The pools are sharded (kv_heads -> head-group axis, within-page rows ->
# page-row axis); weights and activations enter replicated and each shard
# slices its own projection columns by axis index.  Every shard attends
# over its local KV rows only; the page-row sub-shards of a kv-head group
# merge their partial outputs exactly via the log-sum-exp combination
# (core/distributed_decode.merge_partial_attention), then the O-proj runs
# row-parallel over per-shard query-head slices with a tiling-AllReduce
# (core/tiled_allreduce.matmul_allreduce) over the whole mesh.

def _tp_pool_spec(plan) -> P:
    """(Hkv, P, ps, D) pools: kv heads over the head-group axis,
    within-page rows over the page-row axis.  The page axis stays third
    from the end (serving/pressure.py PAGE_AXIS_FROM_END)."""
    heads_ax, seq_ax = plan.axes
    return P(heads_ax, None, seq_ax, None)


def _tp_slice_attn_params(params, cfg: ModelConfig, gi, si, plan):
    """This shard's projection slices.  QKV are column-parallel over the
    kv-head group (all ``s`` page-row sub-shards of a group compute the
    group's full Q -- they need every query head for the LSE merge);
    the O-proj is row-parallel over the shard's 1/s query-head slice.
    Head blocks are contiguous column/row runs, so slices are dynamic
    (``gi``/``si`` are traced axis indices)."""
    dh = cfg.head_dim
    kvl = cfg.num_kv_heads // plan.g       # kv heads per group
    hq_g = cfg.num_heads // plan.g         # q heads per group
    hq_s = hq_g // plan.s                  # q heads per O-proj row slice
    q0, k0 = gi * hq_g * dh, gi * kvl * dh

    def cols(w, off, n):
        return jax.lax.dynamic_slice_in_dim(w, off, n, axis=1)

    p = {"wq": cols(params["wq"], q0, hq_g * dh),
         "wk": cols(params["wk"], k0, kvl * dh),
         "wv": cols(params["wv"], k0, kvl * dh)}
    if "bq" in params:
        p["bq"] = jax.lax.dynamic_slice_in_dim(params["bq"], q0,
                                               hq_g * dh, 0)
        p["bk"] = jax.lax.dynamic_slice_in_dim(params["bk"], k0,
                                               kvl * dh, 0)
        p["bv"] = jax.lax.dynamic_slice_in_dim(params["bv"], k0,
                                               kvl * dh, 0)
    o0 = (gi * hq_g + si * hq_s) * dh      # global first row of the slice
    wo = jax.lax.dynamic_slice_in_dim(params["wo"], o0, hq_s * dh, axis=0)
    return p, wo, hq_s


def _tp_o_proj(merged, wo_loc, si, hq_s, dtype, plan):
    """Row-parallel O-proj of the merged attention output.

    merged: (B, Hq_group, Sq, D) f32, identical on every page-row
    sub-shard of the group after the LSE merge; each shard contributes
    its 1/s query-head slice against its wo row block, summed over the
    WHOLE mesh (g*s disjoint row blocks) by the tiling-AllReduce."""
    b, _, sq, d = merged.shape
    sl = jax.lax.dynamic_slice_in_dim(merged, si * hq_s, hq_s, axis=1)
    o = sl.astype(dtype).transpose(0, 2, 1, 3).reshape(b * sq, hq_s * d)
    y = matmul_allreduce(o, wo_loc, plan.axes, mode=plan.collectives,
                         n_chunks=plan.ar_chunks,
                         first_chunk_frac=plan.first_chunk_frac)
    return y.reshape(b, sq, -1)


def _tp_attention_decode_paged(params, x, cfg: ModelConfig,
                               cache: KVCache, *, page_table, pos,
                               window: Optional[int], tpc):
    plan, mesh = tpc.plan, tpc.mesh
    heads_ax, seq_ax = plan.axes
    pool_spec = _tp_pool_spec(plan)

    def body(prm, xb, kp, vp, table, posb):
        gi = jax.lax.axis_index(heads_ax)
        si = jax.lax.axis_index(seq_ax)
        b = xb.shape[0]
        sp, wo_loc, hq_s = _tp_slice_attn_params(prm, cfg, gi, si, plan)
        positions = posb.astype(jnp.int32)[:, None]
        if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
            positions = jnp.broadcast_to(positions, (3, b, 1))
        q, k_new, v_new = _project_qkv(sp, xb, cfg, positions)
        # masked single-row write: only the sub-shard owning the row's
        # within-page offset writes it; everyone else redirects into its
        # local slice of the scratch page
        psl = kp.shape[2]
        ps = psl * plan.s
        page = table[jnp.arange(b), posb // ps]
        off = posb % ps
        own = (off // psl) == si
        page_t = jnp.where(own, page, 0)
        off_t = jnp.where(own, off % psl, 0)
        kp = kp.at[:, page_t, off_t].set(
            k_new[:, 0].astype(kp.dtype).transpose(1, 0, 2))
        vp = vp.at[:, page_t, off_t].set(
            v_new[:, 0].astype(vp.dtype).transpose(1, 0, 2))
        kv_len = posb.astype(jnp.int32) + 1
        kv_pos = paged_shard_kv_positions(table.shape[1], ps, psl, si)
        out, lse = attend_with_positions(
            q.transpose(0, 2, 1, 3), paged_local_view(kp, table),
            paged_local_view(vp, table),
            q_positions=(kv_len - 1)[:, None], kv_positions=kv_pos,
            kv_len=kv_len, causal=True, window=window,
            softcap=cfg.attn_logit_softcap)
        merged = merge_partial_attention(out, lse, seq_ax)
        return _tp_o_proj(merged, wo_loc, si, hq_s, xb.dtype, plan), kp, vp

    out, k, v = _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), pool_spec, pool_spec, P(), P()),
        out_specs=(P(), pool_spec, pool_spec),
        check_vma=False)(params, x, cache.k, cache.v, page_table, pos)
    return out, KVCache(k, v)


def _tp_attention_prefill_paged(params, x, cfg: ModelConfig,
                                cache: KVCache, *, page_table, pos_start,
                                n_valid, window: Optional[int], tpc):
    plan, mesh = tpc.plan, tpc.mesh
    heads_ax, seq_ax = plan.axes
    pool_spec = _tp_pool_spec(plan)

    def body(prm, xb, kp, vp, table, p0, nv):
        gi = jax.lax.axis_index(heads_ax)
        si = jax.lax.axis_index(seq_ax)
        b, s, _ = xb.shape
        sp, wo_loc, hq_s = _tp_slice_attn_params(prm, cfg, gi, si, plan)
        positions = p0.astype(jnp.int32)[:, None] + \
            jnp.arange(s, dtype=jnp.int32)[None]
        rope_pos = positions
        if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
            rope_pos = jnp.broadcast_to(positions, (3, b, s))
        q, k_new, v_new = _project_qkv(sp, xb, cfg, rope_pos)
        # chunk scatter, owner rows only: padding rows and rows owned by
        # other page-row sub-shards land in the local scratch slice
        kvl, npages, psl, d = kp.shape
        ps = psl * plan.s
        page = table[jnp.arange(b)[:, None], positions // ps]
        off = positions % ps
        valid = jnp.arange(s, dtype=jnp.int32)[None] < nv[:, None]
        own = (off // psl) == si
        flat = jnp.where(valid & own, page * psl + off % psl, 0)
        kp = kp.reshape(kvl, npages * psl, d).at[:, flat].set(
            k_new.astype(kp.dtype).transpose(2, 0, 1, 3)
        ).reshape(kvl, npages, psl, d)
        vp = vp.reshape(kvl, npages * psl, d).at[:, flat].set(
            v_new.astype(vp.dtype).transpose(2, 0, 1, 3)
        ).reshape(kvl, npages, psl, d)
        kv_len = p0.astype(jnp.int32) + nv.astype(jnp.int32)
        kv_pos = paged_shard_kv_positions(table.shape[1], ps, psl, si)
        out, lse = attend_with_positions(
            q.transpose(0, 2, 1, 3), paged_local_view(kp, table),
            paged_local_view(vp, table),
            q_positions=positions, kv_positions=kv_pos, kv_len=kv_len,
            causal=True, window=window, softcap=cfg.attn_logit_softcap)
        merged = merge_partial_attention(out, lse, seq_ax)
        return _tp_o_proj(merged, wo_loc, si, hq_s, xb.dtype, plan), kp, vp

    out, k, v = _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), pool_spec, pool_spec, P(), P(), P()),
        out_specs=(P(), pool_spec, pool_spec),
        check_vma=False)(params, x, cache.k, cache.v, page_table,
                         pos_start, n_valid)
    return out, KVCache(k, v)
