"""GQA attention layer wired to the FastAttention core.

Distribution (DESIGN.md §4.1): activations are sequence-sharded on the
`model` axis (context parallelism).  Q keeps its seq sharding; K/V are
constrained replicated along `model` (one small GQA KV all-gather per
layer), so the flash scan partitions cleanly over Q rows with zero extra
collectives.  At decode time the KV cache is instead sharded along its
*sequence* dim (`kv_seq -> model`); XLA decomposes the softmax/PV
reductions over the sharded dim into exactly the LSE-merge collectives of
core/distributed_decode.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.fastattention import (default_paged_impl, fast_attention,
                                      fast_attention_decode,
                                      fast_attention_prefill_paged)
from repro.layers import common, rotary
from repro.sharding.rules import constrain

# Decode KV-cache layout: "bshd" (token-major, default) or "bhsd"
# (head-major: the QK/PV contractions need no transposed copy of the
# cache -- decode hillclimb iteration, EXPERIMENTS.md §Perf cell 3).
KV_CACHE_LAYOUT = "bshd"


class KVCache(NamedTuple):
    k: jax.Array            # (B, S_max, Hkv, D) or (B, Hkv, S_max, D)
    v: jax.Array


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d, qd, dtype),
        "wk": common.dense_init(ks[1], d, kvd, dtype),
        "wv": common.dense_init(ks[2], d, kvd, dtype),
        "wo": common.dense_init(ks[3], qd, d, dtype, scale=qd ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def attention_logical(cfg: ModelConfig):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": (("attn_row", "heads"), (d, qd)),
        "wk": (("attn_row", "heads"), (d, kvd)),
        "wv": (("attn_row", "heads"), (d, kvd)),
        "wo": (("attn_row", "d_model"), (qd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = (("heads",), (qd,))
        p["bk"] = (("heads",), (kvd,))
        p["bv"] = (("heads",), (kvd,))
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    q = common.dense(x, params["wq"], params.get("bq"))
    k = common.dense(x, params["wk"], params.get("bk"))
    v = common.dense(x, params["wv"], params.get("bv"))
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.rope_type == "rope":
        q = rotary.apply_rope(q, positions, theta=cfg.rope_theta)
        k = rotary.apply_rope(k, positions, theta=cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = rotary.apply_mrope(q, positions, sections=cfg.mrope_sections,
                               theta=cfg.rope_theta)
        k = rotary.apply_mrope(k, positions, sections=cfg.mrope_sections,
                               theta=cfg.rope_theta)
    return q, k, v


def apply_attention(params, x, cfg: ModelConfig, *,
                    positions, window: Optional[int] = None,
                    causal: bool = True,
                    impl: Optional[str] = None) -> jax.Array:
    """Training/prefill attention.  x: (B, S, D) seq-sharded."""
    impl = impl or cfg.attention_impl
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = constrain(q, "batch", "seq", "heads", None)
    # context parallelism: KV replicated along `model` (GQA keeps it small)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    out = fast_attention(q, k, v, causal=causal, window=window,
                         softcap=cfg.attn_logit_softcap, impl=impl)
    out = constrain(out, "batch", "seq", "heads", None)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.q_dim)
    return common.dense(out, params["wo"])


def apply_cross_attention(params, x, enc_k, enc_v, cfg: ModelConfig, *,
                          impl: Optional[str] = None) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V."""
    impl = impl or cfg.attention_impl
    b, s, _ = x.shape
    q = common.dense(x, params["wq"], params.get("bq"))
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    q = constrain(q, "batch", "seq", "heads", None)
    out = fast_attention(q, enc_k, enc_v, causal=False,
                         softcap=cfg.attn_logit_softcap, impl=impl)
    out = out.reshape(b, s, cfg.q_dim)
    return common.dense(out, params["wo"])


def project_cross_kv(params, enc_states, cfg: ModelConfig):
    b, s, _ = enc_states.shape
    k = common.dense(enc_states, params["wk"], params.get("bk"))
    v = common.dense(enc_states, params["wv"], params.get("bv"))
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return constrain(k, "batch", None, "heads", None), \
        constrain(v, "batch", None, "heads", None)


def apply_attention_decode(params, x, cfg: ModelConfig, cache: KVCache, *,
                           pos, window: Optional[int] = None,
                           impl: Optional[str] = None):
    """One-token decode.  x: (B, 1, D); pos: scalar current position.

    Returns (out (B,1,D), new_cache).  The cache sequence dim carries the
    `kv_seq -> model` sharding (context-parallel decode).
    """
    impl = impl or cfg.attention_impl
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    if KV_CACHE_LAYOUT == "bhsd":
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype).transpose(0, 2, 1, 3),
            (0, 0, pos, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype).transpose(0, 2, 1, 3),
            (0, 0, pos, 0))
        k = constrain(k, "batch", "heads", "kv_seq", None)
        v = constrain(v, "batch", "heads", "kv_seq", None)
    else:
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0))
        k = constrain(k, "batch", "kv_seq", "heads", None)
        v = constrain(v, "batch", "kv_seq", "heads", None)
    kv_len = jnp.full((b,), pos + 1, jnp.int32)
    out = fast_attention_decode(
        q, k, v, kv_len, window=window, softcap=cfg.attn_logit_softcap,
        impl="reference" if impl == "reference" else impl,
        layout=KV_CACHE_LAYOUT)
    out = out.reshape(b, 1, cfg.q_dim)
    return common.dense(out, params["wo"]), KVCache(k, v)


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype) -> KVCache:
    if KV_CACHE_LAYOUT == "bhsd":
        shape = (batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
    else:
        shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# Paged decode: KV pages shared across sequences via a page table
# ---------------------------------------------------------------------------

def init_kv_pages(cfg: ModelConfig, num_pages: int, page_size: int,
                  dtype) -> KVCache:
    """Global page pools (Hkv, P, page_size, D).  Every sequence's cache
    is a subset of pages named by its page-table row; batch size does not
    appear in the storage shape -- the pool is the memory budget."""
    shape = (cfg.num_kv_heads, num_pages, page_size, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def scatter_kv_pages(cache: KVCache, k_new, v_new, page_table, positions,
                     n_valid) -> KVCache:
    """Scatter a chunk of new K/V rows into the paged pools.

    k_new/v_new: (B, S, Hkv, D); positions: (B, S) int32 global token
    positions; n_valid: (B,) int32 -- rows past it (chunk padding) are
    redirected into the scratch page so fixed-size chunks never touch
    pages owned by live sequences.  The pages covering the valid
    positions must already be materialised in ``page_table``.
    """
    hkv, npages, ps, d = cache.k.shape
    b, s = positions.shape
    page = page_table[jnp.arange(b)[:, None], positions // ps]   # (B, S)
    flat = page * ps + positions % ps
    valid = jnp.arange(s, dtype=jnp.int32)[None] < n_valid[:, None]
    flat = jnp.where(valid, flat, 0)          # padding -> scratch page 0
    # (B, S, Hkv, D) -> (Hkv, B, S, D) rows scattered at flat [b, s]
    k = cache.k.reshape(hkv, npages * ps, d).at[:, flat].set(
        k_new.astype(cache.k.dtype).transpose(2, 0, 1, 3))
    v = cache.v.reshape(hkv, npages * ps, d).at[:, flat].set(
        v_new.astype(cache.v.dtype).transpose(2, 0, 1, 3))
    return KVCache(k.reshape(hkv, npages, ps, d),
                   v.reshape(hkv, npages, ps, d))


def apply_attention_prefill_paged(params, x, cfg: ModelConfig,
                                  cache: KVCache, *, page_table, pos_start,
                                  n_valid, window: Optional[int] = None,
                                  impl: Optional[str] = None):
    """Chunked prefill against paged KV pools: one prompt chunk through
    full (not per-token) attention.

    x: (B, S_chunk, D) -- a fixed-size chunk, possibly padded past
    ``n_valid``; pos_start: (B,) int32 global position of each sequence's
    chunk start; page_table: (B, n_kv) int32.  The chunk's K/V rows are
    scattered into their pages (padding rows into scratch), then the
    chunk attends to every cached position <= its own through the page
    table.  All offsets are runtime values: one jit trace serves every
    chunk of every prompt.  Returns (out (B, S_chunk, D), new pools);
    output rows past ``n_valid`` are garbage and must be ignored.
    """
    impl = impl or default_paged_impl()
    b, s, _ = x.shape
    positions = pos_start.astype(jnp.int32)[:, None] + \
        jnp.arange(s, dtype=jnp.int32)[None]
    rope_pos = positions
    if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
        rope_pos = jnp.broadcast_to(positions, (3, b, s))
    q, k_new, v_new = _project_qkv(params, x, cfg, rope_pos)
    cache = scatter_kv_pages(cache, k_new, v_new, page_table, positions,
                             n_valid)
    kv_len = pos_start.astype(jnp.int32) + n_valid.astype(jnp.int32)
    out = fast_attention_prefill_paged(
        q, cache.k, cache.v, page_table, pos_start, kv_len,
        window=window, softcap=cfg.attn_logit_softcap, impl=impl)
    out = out.reshape(b, s, cfg.q_dim)
    return common.dense(out, params["wo"]), cache


def apply_attention_decode_paged(params, x, cfg: ModelConfig,
                                 cache: KVCache, *, page_table, pos,
                                 window: Optional[int] = None,
                                 impl: Optional[str] = None):
    """One-token decode against paged KV pools.

    x: (B, 1, D); pos: (B,) int32 per-sequence positions (ragged batch --
    unlike the dense path there is no shared scalar position);
    page_table: (B, n_kv) int32.  The new K/V row is scattered into page
    ``page_table[b, pos // page_size]`` at offset ``pos % page_size``;
    attention then reads kv_len = pos + 1 tokens through the table.
    Returns (out (B, 1, D), new KVCache of pools).
    """
    impl = impl or default_paged_impl()
    b = x.shape[0]
    positions = pos.astype(jnp.int32)[:, None]
    if cfg.rope_type == "mrope":   # text continuation: t=h=w=pos
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    ps = cache.k.shape[2]
    page = page_table[jnp.arange(b), pos // ps]
    off = pos % ps
    # (B, 1, Hkv, D) -> (Hkv, B, D) rows scattered at [:, page[b], off[b]]
    k = cache.k.at[:, page, off].set(
        k_new[:, 0].astype(cache.k.dtype).transpose(1, 0, 2))
    v = cache.v.at[:, page, off].set(
        v_new[:, 0].astype(cache.v.dtype).transpose(1, 0, 2))
    kv_len = pos.astype(jnp.int32) + 1
    out = fast_attention_decode(
        q, k, v, kv_len, window=window, softcap=cfg.attn_logit_softcap,
        impl=impl, page_table=page_table)
    out = out.reshape(b, 1, cfg.q_dim)
    return common.dense(out, params["wo"]), KVCache(k, v)
