"""Parameter initialization helpers (functional, flax-free).

Parameters are nested dicts of jnp arrays.  Each initializer also records
the *logical axes* of every leaf in a parallel tree (same structure, leaves
are ``(logical_axes_tuple, shape)``) consumed by sharding.rules.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype,
               logical=("d_model", "ff"), scale: Optional[float] = None):
    scale = scale if scale is not None else in_dim ** -0.5
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return w.astype(dtype)


def dense_logical(in_dim, out_dim, logical):
    return (tuple(logical), (in_dim, out_dim))


def dense(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None,
          dtype=None) -> jax.Array:
    dtype = dtype or x.dtype
    y = jnp.einsum("...d,df->...f", x, w.astype(dtype))
    if bias is not None:
        y = y + bias.astype(dtype)
    return y


def split_keys(key, n: int):
    return jax.random.split(key, n)


def stack_params(param_list: Sequence):
    """Stack a list of identical param trees along a new leading layer dim
    (for lax.scan over layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *param_list)


def stack_logical(logical_tree):
    """Add the 'layers' logical axis to every leaf of a logical tree."""
    from repro.sharding.rules import is_logical_leaf

    def add(leaf):
        logical, shape = leaf
        return (("layers",) + logical, (None,) + tuple(shape))
    return jax.tree.map(add, logical_tree, is_leaf=is_logical_leaf)
