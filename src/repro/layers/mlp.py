"""Dense MLP blocks: SwiGLU / GeGLU / GELU, column->row parallel on `ff`."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import common
from repro.sharding.rules import constrain


def init_mlp(key, d: int, f: int, mlp_type: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": common.dense_init(ks[0], d, f, dtype),
         "w_down": common.dense_init(ks[1], f, d, dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = common.dense_init(ks[2], d, f, dtype)
    return p


def mlp_logical(d: int, f: int, mlp_type: str):
    p = {"w_up": (("d_model", "ff"), (d, f)),
         "w_down": (("ff", "d_model"), (f, d))}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (("d_model", "ff"), (d, f))
    return p


def apply_mlp(params, x, mlp_type: str = "swiglu"):
    h = common.dense(x, params["w_up"])
    if mlp_type == "swiglu":
        h = jax.nn.silu(common.dense(x, params["w_gate"])) * h
    elif mlp_type == "geglu":
        h = jax.nn.gelu(common.dense(x, params["w_gate"])) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "ff")
    return common.dense(h, params["w_down"])
