"""Dense MLP blocks: SwiGLU / GeGLU / GELU, column->row parallel on `ff`."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map as _shard_map
from repro.core.tiled_allreduce import matmul_allreduce
from repro.layers import common
from repro.sharding.rules import constrain
from repro.sharding.tp import current_tp


def init_mlp(key, d: int, f: int, mlp_type: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": common.dense_init(ks[0], d, f, dtype),
         "w_down": common.dense_init(ks[1], f, d, dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = common.dense_init(ks[2], d, f, dtype)
    return p


def mlp_logical(d: int, f: int, mlp_type: str):
    p = {"w_up": (("d_model", "ff"), (d, f)),
         "w_down": (("ff", "d_model"), (f, d))}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (("d_model", "ff"), (d, f))
    return p


def apply_mlp(params, x, mlp_type: str = "swiglu"):
    tpc = current_tp()
    if tpc is not None and params["w_up"].shape[1] % tpc.plan.tp == 0:
        return _tp_apply_mlp(params, x, mlp_type, tpc)
    h = common.dense(x, params["w_up"])
    if mlp_type == "swiglu":
        h = jax.nn.silu(common.dense(x, params["w_gate"])) * h
    elif mlp_type == "geglu":
        h = jax.nn.gelu(common.dense(x, params["w_gate"])) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "ff")
    return common.dense(h, params["w_down"])


def _tp_apply_mlp(params, x, mlp_type: str, tpc):
    """Megatron column->row parallel MLP over the paged-TP mesh.

    Inputs enter replicated; each shard takes a 1/tp column slice of
    w_up/w_gate (indexed by its linear mesh position), applies the
    activation on its ff slice, and multiplies against the matching
    w_down row slice, partial-summed over both mesh axes with the
    tiling-AllReduce.  Falls back to the replicated path (caller) when
    d_ff does not divide tp.
    """
    plan, mesh = tpc.plan, tpc.mesh
    heads_ax, seq_ax = plan.axes
    tp, fl = plan.tp, params["w_up"].shape[1] // plan.tp

    def body(prm, xb):
        li = jax.lax.axis_index(heads_ax) * plan.s + \
            jax.lax.axis_index(seq_ax)
        f0 = li * fl
        w_up = jax.lax.dynamic_slice_in_dim(prm["w_up"], f0, fl, axis=1)
        h = common.dense(xb, w_up)
        if mlp_type == "swiglu":
            w_gate = jax.lax.dynamic_slice_in_dim(prm["w_gate"], f0, fl, 1)
            h = jax.nn.silu(common.dense(xb, w_gate)) * h
        elif mlp_type == "geglu":
            w_gate = jax.lax.dynamic_slice_in_dim(prm["w_gate"], f0, fl, 1)
            h = jax.nn.gelu(common.dense(xb, w_gate)) * h
        else:
            h = jax.nn.gelu(h)
        w_down = jax.lax.dynamic_slice_in_dim(prm["w_down"], f0, fl, axis=0)
        b, s, _ = xb.shape
        y = matmul_allreduce(h.reshape(b * s, fl), w_down, plan.axes,
                             mode=plan.collectives, n_chunks=plan.ar_chunks,
                             first_chunk_frac=plan.first_chunk_frac)
        return y.reshape(b, s, -1)

    return _shard_map(body, mesh=mesh, in_specs=(P(), P()),
                      out_specs=P(), check_vma=False)(params, x)
