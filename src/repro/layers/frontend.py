"""Modality frontend STUBS (per assignment: [audio]/[vlm] entries specify
the transformer backbone only; input_specs() provides precomputed
frame/patch embeddings).

The stubs are linear adapters from precomputed embeddings into d_model so
the backbone sees correctly-shaped, trainable inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import common


def init_frontend(key, cfg: ModelConfig, dtype):
    if cfg.modality == "text":
        return {}
    ks = jax.random.split(key, 2)
    return {
        "adapter": common.dense_init(ks[0], cfg.d_model, cfg.d_model, dtype),
        "pos_embed": (jax.random.normal(
            ks[1], (cfg.encoder_seq if cfg.modality == "audio_stub" else 1,
                    cfg.d_model), jnp.float32) * 0.02).astype(dtype),
    }


def frontend_logical(cfg: ModelConfig):
    if cfg.modality == "text":
        return {}
    rows = cfg.encoder_seq if cfg.modality == "audio_stub" else 1
    return {
        "adapter": (("d_model", None), (cfg.d_model, cfg.d_model)),
        "pos_embed": ((None, "d_model"), (rows, cfg.d_model)),
    }


def apply_frontend(params, embeds: jax.Array, cfg: ModelConfig):
    """embeds: precomputed (B, S, D) frame/patch embeddings (stub input)."""
    x = common.dense(embeds, params["adapter"])
    pe = params["pos_embed"]
    if pe.shape[0] == x.shape[1]:
        x = x + pe[None].astype(x.dtype)
    return x
