"""Token embedding + LM head (vocab-sharded on `model`)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding.rules import constrain


def init_embedding(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    p = {"embed": (jax.random.normal(
        ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
        * cfg.d_model ** -0.5).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5).astype(dtype)
    return p


def embedding_logical(cfg: ModelConfig):
    p = {"embed": (("vocab", "d_model"), (cfg.vocab_size, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["lm_head"] = (("d_model", "vocab"),
                        (cfg.d_model, cfg.vocab_size))
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(x, "batch", "seq", None)


def lm_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return constrain(logits, "batch", "seq", "vocab")
