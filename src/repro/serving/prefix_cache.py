"""Radix-tree prefix cache: cross-request KV reuse over shared pages.

Production traffic is dominated by shared prompt prefixes (system
prompts, few-shot templates, multi-turn histories).  Because the paged
attention kernels read KV strictly through a per-slot page table, two
sequences whose token prefixes agree can point their table rows at the
*same physical pages* -- the SGLang/FlashInfer observation -- with zero
kernel changes.  This module owns the host-side index that makes the
match: a radix tree over **page-sized token blocks**.

Each tree node is one full page of tokens (key: the ``page_size`` token
ids) mapping to the physical page holding that block's K/V.  A node's
path from the root spells the whole token prefix, so the KV in its page
-- which depends on every earlier position -- is valid for exactly the
sequences that reach it.  Matching therefore walks full blocks only:
page-aligned by construction, never a partial page.

Lifecycle:

* ``insert`` (at sequence retire) publishes a sequence's full prefix
  blocks, taking one cache reference per newly created node so the
  pages stay resident after their writer's slot is freed.
* ``match`` (at admission) returns the longest cached page run for a
  token sequence and touches the path's LRU clock.
* ``evict`` (free list running low, or the ``capacity_pages`` soft cap)
  removes least-recently-used **leaves** whose page only the index still
  references -- a page some live slot shares is never reclaimed from
  under it.  Removing a leaf may expose its parent as the next
  candidate, so long dead branches unwind back-to-front.

The index never touches device memory: it holds references via
``PagedKVCache.incref``/``decref`` and deals purely in page numbers.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import PagedKVCache


class _Node:
    """One page-sized token block: ``block`` (token-id tuple) -> the
    physical ``page`` holding its KV."""
    __slots__ = ("block", "page", "children", "parent", "last_used")

    def __init__(self, block, page: int, parent: Optional["_Node"],
                 last_used: int):
        self.block = block
        self.page = page
        self.children: Dict[tuple, "_Node"] = {}
        self.parent = parent
        self.last_used = last_used


class RadixPrefixIndex:
    """Token-block radix tree mapping page-aligned prompt prefixes to
    resident physical page runs of a :class:`PagedKVCache`."""

    def __init__(self, cache: PagedKVCache, page_size: Optional[int] = None,
                 capacity_pages: int = 0, *, metrics=None):
        self.cache = cache
        self.page_size = page_size or cache.page_size
        # cap on index-held pages (0 = unbounded, the pool is the bound)
        self.capacity_pages = capacity_pages
        self._root = _Node(None, -1, None, 0)
        self._clock = 0
        self._nodes = 0
        self.stats = {"hits": 0, "misses": 0, "hit_tokens": 0,
                      "inserted_blocks": 0, "evicted_blocks": 0,
                      "freed_pages": 0}
        # optional MetricsRegistry (serving/metrics.py): the stats dict
        # stays the authority stats() exposes, the registry mirrors each
        # key as a cumulative ``prefix_<key>_total`` counter
        self._counters = ({k: metrics.counter(f"prefix_{k}_total")
                           for k in self.stats}
                          if metrics is not None else None)

    def _bump(self, key: str, n: int = 1) -> None:
        self.stats[key] += n
        if self._counters is not None:
            self._counters[key].inc(n)

    # -- introspection --------------------------------------------------
    def __len__(self) -> int:
        return self._nodes

    @property
    def cached_pages(self) -> int:
        """Pages the index holds references on (== node count)."""
        return self._nodes

    def page_refs(self) -> Dict[int, int]:
        """page -> number of index references, for
        ``PagedKVCache.check_invariants(extern_refs=...)``."""
        refs: Dict[int, int] = {}
        for node in self._walk():
            refs[node.page] = refs.get(node.page, 0) + 1
        return refs

    def _walk(self) -> List[_Node]:
        out, stack = [], list(self._root.children.values())
        while stack:
            node = stack.pop()
            out.append(node)
            stack.extend(node.children.values())
        return out

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _blocks(self, tokens) -> List[tuple]:
        tokens = np.asarray(tokens).reshape(-1)
        ps = self.page_size
        return [tuple(int(t) for t in tokens[i:i + ps])
                for i in range(0, (len(tokens) // ps) * ps, ps)]

    # -- match / insert / evict -----------------------------------------
    def match(self, tokens, record: bool = True) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: returns ``(pages,
        matched_tokens)`` with ``matched_tokens`` a whole number of
        pages.  Touches the matched path's LRU clock.  ``record=False``
        leaves the hit/miss stats alone -- the scheduler probes the
        index on every admission attempt (a blocked head-of-queue
        request re-plans each engine step) and records only the match
        an admission actually consumes, via ``record_match``."""
        now = self._tick()
        node, pages = self._root, []
        for block in self._blocks(tokens):
            child = node.children.get(block)
            if child is None:
                break
            child.last_used = now
            pages.append(child.page)
            node = child
        matched = len(pages) * self.page_size
        if record:
            self.record_match(matched)
        return pages, matched

    def record_match(self, matched_tokens: int) -> None:
        """Count one consumed match in the hit/miss stats."""
        self._bump("hits" if matched_tokens else "misses")
        self._bump("hit_tokens", matched_tokens)

    def insert(self, tokens, pages: List[int]) -> int:
        """Publish the full blocks of ``tokens`` backed by ``pages``
        (one physical page per block, already resident).  Existing nodes
        are kept -- a duplicate block computed by a concurrent cold run
        keeps the first-published page and the newcomer's copy simply
        loses its last reference at retire.  Returns the number of new
        nodes (pages the index took a reference on)."""
        blocks = self._blocks(tokens)
        if len(blocks) != len(pages):
            raise ValueError(
                f"{len(blocks)} full blocks but {len(pages)} pages")
        now = self._tick()
        node, new = self._root, 0
        for block, page in zip(blocks, pages):
            child = node.children.get(block)
            if child is None:
                self.cache.incref(page)
                child = _Node(block, page, node, now)
                node.children[block] = child
                self._nodes += 1
                new += 1
            else:
                child.last_used = now
            node = child
        self._bump("inserted_blocks", new)
        self.trim_to_capacity()
        return new

    def _evictable_leaves(self, free_only: bool) -> List[_Node]:
        return [n for n in self._walk()
                if not n.children
                and (not free_only or self.cache.refcount(n.page) == 1)]

    def _remove_leaf(self, leaf: _Node) -> bool:
        del leaf.parent.children[leaf.block]
        self._nodes -= 1
        self._bump("evicted_blocks")
        freed = self.cache.decref(leaf.page)
        self._bump("freed_pages", freed)
        return freed

    def evict(self, n_pages: int) -> int:
        """LRU-leaf eviction for page pressure: remove least-recently-
        used leaves until ``n_pages`` pages have actually returned to
        the free list (or nothing evictable remains).  Only leaves whose
        page the index alone references are touched -- eviction must
        produce free pages, not strip index entries off live sharers.
        Returns the number of pages freed."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves(free_only=True)
            if not leaves:
                break
            freed += self._remove_leaf(min(leaves,
                                           key=lambda n: n.last_used))
        return freed

    def trim_to_capacity(self) -> None:
        """Enforce the ``capacity_pages`` cap on index-held pages by
        dropping LRU leaves (shared or not -- a live sharer keeps its
        own reference, only the index entry goes)."""
        while self.capacity_pages and self._nodes > self.capacity_pages:
            leaves = self._evictable_leaves(free_only=False)
            if not leaves:
                break
            self._remove_leaf(min(leaves, key=lambda n: n.last_used))
