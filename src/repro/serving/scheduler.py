"""Continuous-batching scheduler over the paged KV cache.

FlashInfer/vLLM-style iteration-level scheduling: a fixed grid of decode
slots (``max_batch``) is refilled from a FIFO waiting queue every step --
sequences retire individually the moment they finish, their pages go back
to the free list, and the freed slot admits the next waiting request.
The whole batch never waits for its slowest member.

Admission is *optimistic* by default: a request is admitted as soon as
its prompt fits beside a small ``watermark_pages`` reserve -- worst-case
decode growth is NOT reserved up front (a slot that will generate 10
tokens no longer pins pages for ``max_new_tokens``).  When the pool does
run dry mid-step, the page-pressure subsystem (``serving/pressure.py``)
preempts the newest-admitted sequence(s): their pages are released and
their KV is either swapped to a host page pool or recomputed on resume.
Preempted requests wait in a ``resuming`` queue that ``admit`` serves
ahead of fresh arrivals, oldest arrival first (FIFO fairness).  The PR 1
worst-case-reservation policy survives as ``admission="reserved"`` --
deadlock-free without preemption, but chronically under-subscribed; the
over-subscription bench reports both.

Prefill is a first-class scheduler state (Sarathi-style chunked prefill):
an admitted request is PREFILLING until its whole prompt has been pushed
through the model in ``prefill_chunk``-token chunks; ``prefill_schedule``
plans each engine step's chunk work under a token budget so a long
newcomer prompt never stalls the decode latency of running sequences.
After a preemption the prefill source is the prompt *plus* every already
generated token except the last (``Request.prefill_tokens``), so a
recompute-resumed sequence rebuilds exactly the KV it lost.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import PagedKVCache, pages_needed

WAITING, PREFILLING, RUNNING, PREEMPTED, FINISHED = (
    "WAITING", "PREFILLING", "RUNNING", "PREEMPTED", "FINISHED")


@dataclass
class Request:
    """One generation request flowing through the engine."""
    id: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    state: str = WAITING
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    prefilled: int = 0                 # prefill tokens already in the cache
    # -- page-pressure bookkeeping -------------------------------------
    arrival: int = -1                  # submit order (scheduler-assigned)
    resume_kind: Optional[str] = None  # "swap" | "recompute" after preempt
    resume_len: int = 0                # materialised KV tokens at preempt
    preemptions: int = 0               # times this request was evicted

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens} "
                "(prefill always emits one token)")

    @property
    def target_len(self) -> int:
        """Worst-case cache length: prompt + every new token's KV."""
        return len(self.prompt) + self.max_new_tokens

    @property
    def prefill_tokens(self) -> np.ndarray:
        """Token source for (re)prefill: the prompt, plus -- after a
        preemption of a decoding sequence -- every generated token except
        the last, whose KV is rebuilt by its own next decode step."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated[:-1], np.int32)])

    @property
    def prefill_total(self) -> int:
        """Tokens (re)prefill must materialise before decode resumes.
        Only meaningful while PREFILLING/PREEMPTED -- for a sequence that
        is decoding it grows with ``generated`` and must not be read."""
        return len(self.prompt) + max(0, len(self.generated) - 1)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prefill_total

    @property
    def done(self) -> bool:
        return (len(self.generated) >= self.max_new_tokens
                or (self.eos_id is not None and len(self.generated) > 0
                    and self.generated[-1] == self.eos_id))


class ContinuousBatchScheduler:
    """Admits waiting/resuming requests into free decode slots, schedules
    chunked prefill under a token budget, retires finished sequences,
    reclaims their pages, and picks preemption victims under pressure."""

    def __init__(self, cache: PagedKVCache, max_slots: Optional[int] = None,
                 *, admission: str = "optimistic", watermark_pages: int = 1):
        if admission not in ("optimistic", "reserved"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cache = cache
        self.max_slots = max_slots or cache.max_slots
        assert self.max_slots <= cache.max_slots
        self.admission = admission
        self.watermark_pages = watermark_pages
        self.waiting: deque = deque()
        self.resuming: deque = deque()      # preempted, FIFO by arrival
        self.slots: List[Optional[Request]] = [None] * self.max_slots
        self.finished: List[Request] = []
        self.preempt_count = 0
        self._admit_seq = 0
        self._admitted_at: dict = {}        # id -> admission sequence no.
        self._arrival_seq = 0

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.state != WAITING:
            raise ValueError(f"request {req.id} already {req.state}")
        worst = pages_needed(0, req.target_len, self.cache.page_size)
        if worst > self.cache.max_pages_per_seq:
            raise ValueError(
                f"request {req.id}: target_len {req.target_len} exceeds "
                f"max_seq_len "
                f"{self.cache.max_pages_per_seq * self.cache.page_size}")
        if worst > self.cache.num_pages - 1:
            raise ValueError(
                f"request {req.id}: needs {worst} pages, pool has "
                f"{self.cache.num_pages - 1}")
        req.arrival = self._arrival_seq
        self._arrival_seq += 1
        self.waiting.append(req)

    # -- step phases -----------------------------------------------------
    def _reserved_pages(self) -> int:
        """Worst-case future page demand of everything running (only the
        ``admission="reserved"`` baseline gates on this)."""
        return sum(
            pages_needed(self.cache.seq_len(req.slot), req.target_len,
                         self.cache.page_size)
            for req in self.slots if req is not None)

    def retire(self) -> List[Request]:
        """Retire finished sequences: free their pages and slots."""
        retired = []
        for slot, req in enumerate(self.slots):
            if req is not None and req.done:
                self.cache.free(slot)
                req.state = FINISHED
                req.slot = None
                self.slots[slot] = None
                self._admitted_at.pop(req.id, None)
                self.finished.append(req)
                retired.append(req)
        return retired

    def _admission_need(self, req: Request, resumed: bool) -> int:
        """Pages admission must see available.  Optimistic: what the
        (re)prefill will materialise -- decode growth is preemption's
        problem.  Reserved: the full worst case."""
        if self.admission == "reserved":
            return pages_needed(0, req.target_len, self.cache.page_size)
        n = req.resume_len if (resumed and req.resume_kind == "swap") \
            else req.prefill_total
        return pages_needed(0, n, self.cache.page_size)

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots, resuming queue first (a preempted request
        goes ahead of every fresh arrival), then waiting -- both FIFO, no
        skipping: a large head-of-line request blocks rather than
        starves.  Fresh and recompute-resumed requests enter PREFILLING;
        a swap-resumed request gets its pages re-materialised here
        (``adopt_pages``) and rejoins in its pre-preemption state once
        the engine copies its host KV back."""
        admitted: List[Tuple[int, Request]] = []
        promised = 0                 # pages admitted but not yet allocated
        # snapshot BEFORE admitting: requests admitted this round land in
        # self.slots and would otherwise be counted again via promised
        reserved0 = (self._reserved_pages()
                     if self.admission == "reserved" else 0)
        for slot in range(self.max_slots):
            if self.slots[slot] is not None:
                continue
            if self.resuming:
                req, resumed = self.resuming[0], True
            elif self.waiting:
                req, resumed = self.waiting[0], False
            else:
                break
            need = self._admission_need(req, resumed)
            if self.admission == "reserved":
                headroom = self.cache.free_pages - reserved0 - promised
            else:
                # watermark reserve -- waived while the grid is empty so
                # a lone request can always make progress
                occupied = promised or admitted or any(
                    r is not None for r in self.slots)
                water = self.watermark_pages if occupied else 0
                headroom = self.cache.free_pages - promised - water
            if need > headroom:
                break
            (self.resuming if resumed else self.waiting).popleft()
            if resumed and req.resume_kind == "swap" and req.resume_len:
                # swap-in: materialise the pages now; the engine scatters
                # the host-stashed KV into them right after admit()
                self.cache.adopt_pages(slot, req.resume_len)
                req.prefilled = req.resume_len
                req.state = RUNNING if (req.generated and req.prefill_done) \
                    else PREFILLING
            else:
                self.cache.alloc(slot)
                req.prefilled = 0
                req.state = PREFILLING
                promised += need
            req.slot = slot
            self.slots[slot] = req
            self._admitted_at[req.id] = self._admit_seq
            self._admit_seq += 1
            admitted.append((slot, req))
        return admitted

    # -- preemption (page pressure) --------------------------------------
    def preemption_victim(self, protect: Optional[int] = None
                          ) -> Optional[int]:
        """Newest-admitted occupied slot, excluding ``protect`` (the slot
        whose growth triggered the pressure).  Newest-first keeps the
        oldest sequence always progressing -- the liveness argument."""
        cands = [(self._admitted_at[r.id], s)
                 for s, r in enumerate(self.slots)
                 if r is not None and s != protect]
        return max(cands)[1] if cands else None

    def preempt(self, slot: int) -> Request:
        """Evict the sequence in ``slot``: release its pages and park it
        on the resuming queue (kept sorted by arrival so the earliest
        submitted victim resumes first).  The caller (PressureManager)
        must have copied any KV worth keeping off the device and set
        ``resume_kind``/``resume_len`` BEFORE this call."""
        req = self.slots[slot]
        if req is None or req.state not in (PREFILLING, RUNNING):
            raise ValueError(f"slot {slot} not preemptible")
        self.cache.release_pages(slot)
        req.state = PREEMPTED
        req.slot = None
        req.preemptions += 1
        self.slots[slot] = None
        self._admitted_at.pop(req.id, None)
        idx = sum(1 for r in self.resuming if r.arrival < req.arrival)
        self.resuming.insert(idx, req)
        self.preempt_count += 1
        return req

    def prefill_schedule(self, budget: int,
                         chunk: int) -> List[Tuple[int, Request, int, int]]:
        """Plan this step's chunked-prefill work: ``(slot, req, start,
        n_tokens)`` jobs in admission order.  ``budget`` is a soft cap
        rounded up to whole chunks (chunks are fixed-cost launches, so
        sub-chunk budgeting buys nothing): planning stops at the first
        chunk boundary at or past it, overshooting by at most
        ``chunk - 1`` tokens.  Always emits at least one chunk when
        anything is PREFILLING (a zero/tiny budget must not starve
        prefill), and completes oldest prompts first so their first
        token streams out as early as possible."""
        jobs: List[Tuple[int, Request, int, int]] = []
        spent = 0
        for slot, req in self.prefilling():
            start = req.prefilled
            total = req.prefill_total
            while start < total:
                if jobs and spent >= budget:
                    return jobs
                n = min(chunk, total - start)
                jobs.append((slot, req, start, n))
                start += n
                spent += n
        return jobs

    # -- introspection ----------------------------------------------------
    def running(self) -> List[Tuple[int, Request]]:
        """All occupied slots (prefilling or decoding)."""
        return [(s, r) for s, r in enumerate(self.slots) if r is not None]

    def prefilling(self) -> List[Tuple[int, Request]]:
        """Slots still pushing prompt chunks, oldest admission first."""
        return sorted(
            ((s, r) for s, r in enumerate(self.slots)
             if r is not None and r.state == PREFILLING),
            key=lambda sr: self._admitted_at.get(sr[1].id, 0))

    def decoding(self) -> List[Tuple[int, Request]]:
        """Slots with a fully-prefilled sequence producing tokens."""
        return [(s, r) for s, r in enumerate(self.slots)
                if r is not None and r.state == RUNNING]

    @property
    def has_work(self) -> bool:
        return (bool(self.waiting) or bool(self.resuming)
                or any(r is not None for r in self.slots))
