"""Continuous-batching scheduler over the paged KV cache.

FlashInfer/vLLM-style iteration-level scheduling: a fixed grid of decode
slots (``max_batch``) is refilled from a FIFO waiting queue every step --
sequences retire individually the moment they finish, their pages go back
to the free list, and the freed slot admits the next waiting request.
The whole batch never waits for its slowest member.

Admission is *worst-case reserved*: a request is admitted only if the pool
can still hold its full prompt + max_new_tokens after honouring the
worst-case growth of everything already running.  Pages themselves are
allocated lazily (``PagedKVCache.append``), so short-finishing sequences
return their slack early -- the reservation only gates admission, it never
pins physical pages.  This makes the engine deadlock-free without
preemption; preemption/swap is the ROADMAP follow-up that relaxes it.

Prefill is a first-class scheduler state (Sarathi-style chunked prefill):
an admitted request is PREFILLING until its whole prompt has been pushed
through the model in ``prefill_chunk``-token chunks; ``prefill_schedule``
plans each engine step's chunk work under a token budget so a long
newcomer prompt never stalls the decode latency of running sequences.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import PagedKVCache, pages_needed

WAITING, PREFILLING, RUNNING, FINISHED = (
    "WAITING", "PREFILLING", "RUNNING", "FINISHED")


@dataclass
class Request:
    """One generation request flowing through the engine."""
    id: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    state: str = WAITING
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    prefilled: int = 0                 # prompt tokens already in the cache

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens} "
                "(prefill always emits one token)")

    @property
    def target_len(self) -> int:
        """Worst-case cache length: prompt + every new token's KV."""
        return len(self.prompt) + self.max_new_tokens

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= len(self.prompt)

    @property
    def done(self) -> bool:
        return (len(self.generated) >= self.max_new_tokens
                or (self.eos_id is not None and len(self.generated) > 0
                    and self.generated[-1] == self.eos_id))


class ContinuousBatchScheduler:
    """Admits waiting requests into free decode slots, schedules chunked
    prefill under a token budget, retires finished sequences, and
    reclaims their pages."""

    def __init__(self, cache: PagedKVCache, max_slots: Optional[int] = None):
        self.cache = cache
        self.max_slots = max_slots or cache.max_slots
        assert self.max_slots <= cache.max_slots
        self.waiting: deque = deque()
        self.slots: List[Optional[Request]] = [None] * self.max_slots
        self.finished: List[Request] = []
        self._admit_seq = 0
        self._admitted_at: dict = {}        # id -> admission sequence no.

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.state != WAITING:
            raise ValueError(f"request {req.id} already {req.state}")
        worst = pages_needed(0, req.target_len, self.cache.page_size)
        if worst > self.cache.max_pages_per_seq:
            raise ValueError(
                f"request {req.id}: target_len {req.target_len} exceeds "
                f"max_seq_len "
                f"{self.cache.max_pages_per_seq * self.cache.page_size}")
        if worst > self.cache.num_pages - 1:
            raise ValueError(
                f"request {req.id}: needs {worst} pages, pool has "
                f"{self.cache.num_pages - 1}")
        self.waiting.append(req)

    # -- step phases -----------------------------------------------------
    def _reserved_pages(self) -> int:
        """Worst-case future page demand of everything running."""
        return sum(
            pages_needed(self.cache.seq_len(req.slot), req.target_len,
                         self.cache.page_size)
            for req in self.slots if req is not None)

    def retire(self) -> List[Request]:
        """Retire finished sequences: free their pages and slots."""
        retired = []
        for slot, req in enumerate(self.slots):
            if req is not None and req.done:
                self.cache.free(slot)
                req.state = FINISHED
                req.slot = None
                self.slots[slot] = None
                self._admitted_at.pop(req.id, None)
                self.finished.append(req)
                retired.append(req)
        return retired

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the waiting queue (FIFO, no skipping: a
        large head-of-line request blocks rather than starves).  Admitted
        requests enter PREFILLING; the engine flips them to RUNNING once
        their whole prompt is in the cache."""
        admitted = []
        reserved = self._reserved_pages()
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            worst = pages_needed(0, req.target_len, self.cache.page_size)
            if worst > self.cache.free_pages - reserved:
                break
            self.waiting.popleft()
            self.cache.alloc(slot)
            req.state = PREFILLING
            req.prefilled = 0
            req.slot = slot
            self.slots[slot] = req
            self._admitted_at[req.id] = self._admit_seq
            self._admit_seq += 1
            reserved += worst
            admitted.append((slot, req))
        return admitted

    def prefill_schedule(self, budget: int,
                         chunk: int) -> List[Tuple[int, Request, int, int]]:
        """Plan this step's chunked-prefill work: ``(slot, req, start,
        n_tokens)`` jobs in admission order.  ``budget`` is a soft cap
        rounded up to whole chunks (chunks are fixed-cost launches, so
        sub-chunk budgeting buys nothing): planning stops at the first
        chunk boundary at or past it, overshooting by at most
        ``chunk - 1`` tokens.  Always emits at least one chunk when
        anything is PREFILLING (a zero/tiny budget must not starve
        prefill), and completes oldest prompts first so their first
        token streams out as early as possible."""
        jobs: List[Tuple[int, Request, int, int]] = []
        spent = 0
        for slot, req in self.prefilling():
            start = req.prefilled
            while start < len(req.prompt):
                if jobs and spent >= budget:
                    return jobs
                n = min(chunk, len(req.prompt) - start)
                jobs.append((slot, req, start, n))
                start += n
                spent += n
        return jobs

    # -- introspection ----------------------------------------------------
    def running(self) -> List[Tuple[int, Request]]:
        """All occupied slots (prefilling or decoding)."""
        return [(s, r) for s, r in enumerate(self.slots) if r is not None]

    def prefilling(self) -> List[Tuple[int, Request]]:
        """Slots still pushing prompt chunks, oldest admission first."""
        return sorted(
            ((s, r) for s, r in enumerate(self.slots)
             if r is not None and r.state == PREFILLING),
            key=lambda sr: self._admitted_at.get(sr[1].id, 0))

    def decoding(self) -> List[Tuple[int, Request]]:
        """Slots with a fully-prefilled sequence producing tokens."""
        return [(s, r) for s, r in enumerate(self.slots)
                if r is not None and r.state == RUNNING]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(
            r is not None for r in self.slots)
