"""Continuous-batching scheduler over the paged KV cache.

FlashInfer/vLLM-style iteration-level scheduling: a fixed grid of decode
slots (``max_batch``) is refilled from a FIFO waiting queue every step --
sequences retire individually the moment they finish, their pages go back
to the free list, and the freed slot admits the next waiting request.
The whole batch never waits for its slowest member.

Admission is *optimistic* by default: a request is admitted as soon as
its prompt fits beside a small ``watermark_pages`` reserve -- worst-case
decode growth is NOT reserved up front (a slot that will generate 10
tokens no longer pins pages for ``max_new_tokens``).  When the pool does
run dry mid-step, the page-pressure subsystem (``serving/pressure.py``)
preempts the newest-admitted sequence(s): their pages are released and
their KV is either swapped to a host page pool or recomputed on resume.
Preempted requests wait in a ``resuming`` queue that ``admit`` serves
ahead of fresh arrivals, oldest arrival first (FIFO fairness).  The PR 1
worst-case-reservation policy survives as ``admission="reserved"`` --
deadlock-free without preemption, but chronically under-subscribed; the
over-subscription bench reports both.

Prefill is a first-class scheduler state (Sarathi-style chunked prefill):
an admitted request is PREFILLING until its whole prompt has been pushed
through the model in ``prefill_chunk``-token chunks; ``prefill_schedule``
plans each engine step's chunk work under a token budget so a long
newcomer prompt never stalls the decode latency of running sequences.
After a preemption the prefill source is the prompt *plus* every already
generated token except the last (``Request.prefill_tokens``), so a
recompute-resumed sequence rebuilds exactly the KV it lost.

With a ``RadixPrefixIndex`` attached (``ServeConfig.prefix_cache``),
admission first matches the request's tokens against the index: the
longest page-aligned cached prefix is *shared* -- the slot's page-table
row points at the already-resident physical pages
(``PagedKVCache.share_pages``) and chunked prefill starts at
``pos_start = matched_len``, skipping the matched prefix's attention
launches entirely.  A full-prompt hit keeps every page shared and
recomputes exactly one token (the last, whose logits seed sampling);
its write copy-on-writes the shared tail page.  ``retire`` closes the
loop by publishing the finished sequence's full prefix blocks back into
the index, so the pages outlive the slot until LRU eviction reclaims
them under pool pressure.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.faults import InjectedFault, RequestRejected
from repro.serving.paged_cache import (OutOfPages, PagedKVCache,
                                       pages_needed)

WAITING, PREFILLING, RUNNING, PREEMPTED, FINISHED, ABORTED, FAILED = (
    "WAITING", "PREFILLING", "RUNNING", "PREEMPTED", "FINISHED", "ABORTED",
    "FAILED")


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls, carried on the ``Request``.

    Frozen/hashable so a request's sampling behaviour is fixed at submit
    time.  ``seed`` feeds a counter-based RNG stream: the key for the
    request's n-th sampled token is ``fold_in(PRNGKey(seed), n)``, so
    sampled tokens are invariant to batch composition, co-tenants,
    preemption and admission order.  The default is greedy
    (``temperature == 0``) -- the sane serving default; pass a positive
    temperature (and usually a distinct seed) to sample.
    """
    temperature: float = 0.0
    top_k: int = 0                     # 0 = no truncation
    seed: int = 0
    max_new_tokens: int = 16
    # generation stops the step after any of these token ids is emitted
    # (the stop token itself is the request's last token, like eos was)
    stop_token_ids: Tuple[int, ...] = ()
    # generation stops when any of these strings appears in the decoded
    # text of the generated tokens; the matched suffix is trimmed from
    # the emitted stream (tokens that could extend into a stop string
    # are held back until disambiguated).  Requires the engine to have a
    # ``detokenize`` callable.
    stop_strings: Tuple[str, ...] = ()
    # wall-clock deadline relative to submit time, in milliseconds.
    # Expired waiting requests are shed (structured timeout error);
    # expired running requests are aborted cleanly.  None = no deadline.
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens} "
                "(prefill always emits one token)")
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {self.deadline_ms}")
        # normalise any iterable (set, list, ndarray) to a sorted tuple
        # so params stay hashable and comparisons are order-independent
        object.__setattr__(
            self, "stop_token_ids",
            tuple(sorted({int(t) for t in self.stop_token_ids})))
        strings = tuple(dict.fromkeys(str(s) for s in self.stop_strings))
        if any(not s for s in strings):
            raise ValueError("stop_strings must be non-empty strings")
        object.__setattr__(self, "stop_strings", strings)

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0 or self.top_k == 1

    def with_stop(self, eos_id: int) -> "SamplingParams":
        if eos_id in self.stop_token_ids:
            return self
        return dataclasses.replace(
            self, stop_token_ids=self.stop_token_ids + (int(eos_id),))


@dataclass
class Request:
    """One generation request flowing through the engine.

    ``sampling`` is the authority for generation length, stop tokens and
    the sampling distribution.  ``max_new_tokens=`` / ``eos_id=`` remain
    as constructor aliases: without an explicit ``SamplingParams`` they
    build one at resolve time (the engine core fills temperature/top_k
    from the deprecated engine-global ``ServeConfig`` knobs); alongside
    one they override/extend it.
    """
    id: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: Optional[int] = None
    eos_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None
    state: str = WAITING
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    prefilled: int = 0                 # prefill tokens already in the cache
    # -- page-pressure bookkeeping -------------------------------------
    arrival: int = -1                  # submit order (scheduler-assigned)
    resume_kind: Optional[str] = None  # "swap" | "recompute" after preempt
    resume_len: int = 0                # materialised KV tokens at preempt
    preemptions: int = 0               # times this request was evicted
    # -- prefix-cache bookkeeping --------------------------------------
    matched_len: int = 0               # cached tokens shared at admission
    resume_shared_len: int = 0         # shared-prefix tokens at swap-preempt
    # -- fault-tolerance bookkeeping -----------------------------------
    submit_t: float = 0.0              # engine clock at submit (deadlines)
    error: Optional[str] = None        # structured detail when FAILED
    # -- stop-string bookkeeping ---------------------------------------
    emitted: int = 0                   # generated tokens already streamed
    stop_matched: bool = False         # a stop string fired (terminal)
    # -- telemetry (serving/metrics.py) --------------------------------
    # timestamped lifecycle events on the engine clock, appended by the
    # LifecycleTracer at every state transition: ("submitted", t),
    # ("prefilling", t), ("first-token", t), ("preempted:swap", t), ...
    trace: List[Tuple[str, float]] = field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.sampling is not None:
            # fold the constructor aliases into the params: an explicit
            # max_new_tokens= wins, an eos_id= joins the stop set
            sp = self.sampling
            if self.max_new_tokens is not None \
                    and self.max_new_tokens != sp.max_new_tokens:
                sp = dataclasses.replace(
                    sp, max_new_tokens=self.max_new_tokens)
            if self.eos_id is not None:
                sp = sp.with_stop(self.eos_id)
            self.sampling = sp
            self.max_new_tokens = sp.max_new_tokens
        elif self.max_new_tokens is None:
            raise ValueError(
                f"request {self.id}: pass max_new_tokens= or sampling=")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens} "
                "(prefill always emits one token)")

    @property
    def stop_token_ids(self) -> Tuple[int, ...]:
        """Stop set: the sampling params' when resolved, else the legacy
        eos alias (a pre-resolution ``done`` check still honours it)."""
        if self.sampling is not None:
            return self.sampling.stop_token_ids
        return (self.eos_id,) if self.eos_id is not None else ()

    @property
    def target_len(self) -> int:
        """Worst-case cache length: prompt + every new token's KV."""
        return len(self.prompt) + self.max_new_tokens

    @property
    def prefill_tokens(self) -> np.ndarray:
        """Token source for (re)prefill: the prompt, plus -- after a
        preemption of a decoding sequence -- every generated token except
        the last, whose KV is rebuilt by its own next decode step."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated[:-1], np.int32)])

    @property
    def prefill_total(self) -> int:
        """Tokens (re)prefill must materialise before decode resumes.
        Only meaningful while PREFILLING/PREEMPTED -- for a sequence that
        is decoding it grows with ``generated`` and must not be read."""
        return len(self.prompt) + max(0, len(self.generated) - 1)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prefill_total

    @property
    def done(self) -> bool:
        if self.stop_matched:
            return True
        if len(self.generated) >= self.max_new_tokens:
            return True
        stop = self.stop_token_ids
        return bool(stop and self.generated
                    and self.generated[-1] in stop)

    def deadline_expired(self, now: float) -> bool:
        """True when the request carries a deadline and ``now`` (engine
        clock, same units as ``submit_t``) is past it."""
        dl = self.sampling.deadline_ms if self.sampling is not None else None
        return dl is not None and (now - self.submit_t) * 1e3 > dl


class ContinuousBatchScheduler:
    """Admits waiting/resuming requests into free decode slots, schedules
    chunked prefill under a token budget, retires finished sequences,
    reclaims their pages, and picks preemption victims under pressure."""

    def __init__(self, cache: PagedKVCache, max_slots: Optional[int] = None,
                 *, admission: str = "optimistic", watermark_pages: int = 1,
                 prefix_cache=None, tracer=None):
        if admission not in ("optimistic", "reserved"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cache = cache
        # LifecycleTracer (serving/metrics.py) or None: the scheduler
        # owns the admit/preempt/retire transitions, so it reports them;
        # terminal abort/fail spans are the engine core's to close (it
        # alone can tell an abort from a quarantine)
        self.tracer = tracer
        self.max_slots = max_slots or cache.max_slots
        assert self.max_slots <= cache.max_slots
        self.admission = admission
        self.watermark_pages = watermark_pages
        self.prefix_cache = prefix_cache    # RadixPrefixIndex or None
        self.waiting: deque = deque()
        self.resuming: deque = deque()      # preempted, FIFO by arrival
        self.slots: List[Optional[Request]] = [None] * self.max_slots
        # recently retired requests, for introspection.  Bounded: the
        # scheduler now lives on a persistent core, so an unbounded list
        # would grow with every request ever served; ``finished_count``
        # is the monotonic total.
        self.finished: deque = deque(maxlen=4096)  # repro-lint: disable=silent-drop (debug log; finished_count is the monotonic total)
        self.finished_count = 0
        self.preempt_count = 0
        self._admit_seq = 0
        self._admitted_at: dict = {}        # id -> admission sequence no.
        self._arrival_seq = 0

    # -- queue ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Validate and enqueue.  A request that can never fit -- its
        worst case exceeds max_seq_len or the whole pool -- is rejected
        *here*, with a structured ``RequestRejected`` (a ValueError
        subclass), instead of poisoning a later ``step()``."""
        if req.state != WAITING:
            raise ValueError(f"request {req.id} already {req.state}")
        worst = pages_needed(0, req.target_len, self.cache.page_size)
        if worst > self.cache.max_pages_per_seq:
            raise RequestRejected(
                f"request {req.id}: target_len {req.target_len} exceeds "
                f"max_seq_len "
                f"{self.cache.max_pages_per_seq * self.cache.page_size}",
                request_id=req.id)
        if worst > self.cache.num_pages - 1:
            raise RequestRejected(
                f"request {req.id}: needs {worst} pages, pool has "
                f"{self.cache.num_pages - 1}", request_id=req.id)
        req.arrival = self._arrival_seq
        self._arrival_seq += 1
        self.waiting.append(req)

    # -- step phases -----------------------------------------------------
    def _reserved_pages(self) -> int:
        """Worst-case future page demand of everything running (only the
        ``admission="reserved"`` baseline gates on this)."""
        return sum(
            pages_needed(self.cache.seq_len(req.slot), req.target_len,
                         self.cache.page_size)
            for req in self.slots if req is not None)

    def _publish_prefix(self, slot: int, req: Request) -> None:
        """Insert a retiring sequence's full prefix blocks into the
        prefix index so its pages stay resident for future requests.
        Materialised KV covers ``prompt + generated[:-1]`` (the last
        sampled token's KV was never written)."""
        toks = req.prefill_tokens
        n = min(len(toks), self.cache.seq_len(slot))
        blocks = n // self.cache.page_size
        if blocks:
            self.prefix_cache.insert(
                toks[:blocks * self.cache.page_size],
                self.cache.owned_pages(slot)[:blocks])

    def retire(self) -> List[Request]:
        """Retire finished sequences: free their pages and slots (full
        prefix blocks are first published into the prefix index when one
        is attached)."""
        retired = []
        for slot, req in enumerate(self.slots):
            if req is not None and req.done:
                if self.prefix_cache is not None:
                    self._publish_prefix(slot, req)
                self.cache.free(slot)
                req.state = FINISHED
                req.slot = None
                self.slots[slot] = None
                self._admitted_at.pop(req.id, None)
                self.finished.append(req)
                self.finished_count += 1
                if self.tracer is not None:
                    self.tracer.on_retire(req)
                retired.append(req)
        return retired

    def _match_prefix(self, req: Request) -> Tuple[List[int], int]:
        """Longest usable cached prefix for a (re)prefill: whole pages
        only, capped so at least one token is left to compute -- the
        final chunk's logits seed the first sampled token.  A full
        page-aligned hit keeps *all* its pages shared and recomputes
        exactly the last token (whose write copy-on-writes the shared
        tail page)."""
        pages, m = self.prefix_cache.match(req.prefill_tokens,
                                           record=False)
        total = req.prefill_total
        if m >= total:            # full hit (match never exceeds total)
            return pages, total - 1
        return pages[:m // self.cache.page_size], m

    def _resolve_sharing(self, req: Request, resumed: bool):
        """Plan a candidate admission's page sharing: returns
        ``(shared_pages, shared_len, swap_resume)``.  A swap-resumed
        request must re-find its exact preemption-time shared prefix
        (the host stash only covers the exclusive suffix); if the index
        evicted it meanwhile, the resume downgrades to recompute --
        which then prefix-matches like any fresh request."""
        swap_resume = bool(resumed and req.resume_kind == "swap"
                           and req.resume_len)
        if swap_resume and req.resume_shared_len:
            pages, m = self.prefix_cache.match(req.prefill_tokens,
                                               record=False)
            k = req.resume_shared_len
            if m >= k:
                return pages[:k // self.cache.page_size], k, True
            req.resume_kind = "recompute"
            req.resume_shared_len = 0
            swap_resume = False
        if swap_resume or self.prefix_cache is None:
            return [], 0, swap_resume
        pages, m = self._match_prefix(req)
        return pages, m, False

    def _admission_need(self, req: Request, swap_resume: bool,
                        shared_len: int) -> int:
        """Free pages admission must see available, net of the shared
        prefix.  Optimistic: what the (re)prefill will materialise --
        decode growth is preemption's problem.  Reserved: the full worst
        case.  A shared partial tail page (full-prompt hit) costs one
        extra page for its copy-on-write copy."""
        ps = self.cache.page_size
        if self.admission == "reserved":
            shared = -(-shared_len // ps) if shared_len else 0
            need = max(0, pages_needed(0, req.target_len, ps) - shared)
        else:
            n = req.resume_len if swap_resume else req.prefill_total
            need = pages_needed(shared_len, n, ps)
        if shared_len % ps:
            need += 1
        return need

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots, resuming queue first (a preempted request
        goes ahead of every fresh arrival), then waiting -- both FIFO, no
        skipping: a large head-of-line request blocks rather than
        starves.  Fresh and recompute-resumed requests enter PREFILLING
        -- with the longest cached page-aligned prefix shared into their
        page-table row and ``prefilled`` advanced past it; a swap-resumed
        request re-shares its preemption-time prefix and gets its
        exclusive pages re-materialised here, rejoining in its
        pre-preemption state once the engine copies its host KV back.
        When free pages run short, LRU leaves of the prefix index are
        evicted (and the match re-planned) before giving up."""
        admitted: List[Tuple[int, Request]] = []
        promised = 0                 # pages admitted but not yet allocated
        # snapshot BEFORE admitting: requests admitted this round land in
        # self.slots and would otherwise be counted again via promised
        reserved0 = (self._reserved_pages()
                     if self.admission == "reserved" else 0)
        for slot in range(self.max_slots):
            if self.slots[slot] is not None:
                continue
            if self.resuming:
                req, resumed = self.resuming[0], True
            elif self.waiting:
                req, resumed = self.waiting[0], False
            else:
                break
            while True:
                shared_pages, shared_len, swap_resume = \
                    self._resolve_sharing(req, resumed)
                need = self._admission_need(req, swap_resume, shared_len)
                if self.admission == "reserved":
                    headroom = self.cache.free_pages - reserved0 - promised
                else:
                    # watermark reserve -- waived while the grid is empty
                    # so a lone request can always make progress
                    occupied = promised or admitted or any(
                        r is not None for r in self.slots)
                    water = self.watermark_pages if occupied else 0
                    headroom = self.cache.free_pages - promised - water
                if need <= headroom or self.prefix_cache is None:
                    break
                # free list short: reclaim LRU leaves from the prefix
                # index, then re-plan (the evicted pages may have been
                # part of this very match)
                if self.prefix_cache.evict(need - headroom) == 0:
                    break
            if need > headroom:
                break
            (self.resuming if resumed else self.waiting).popleft()
            if swap_resume:
                # swap-in: re-share the surviving prefix, materialise
                # pages for the exclusive suffix; the engine scatters the
                # host-stashed KV into them right after admit()
                self.cache.alloc(slot)
                if shared_len:
                    self.cache.share_pages(slot, shared_pages, shared_len)
                try:
                    self.cache.append(slot, req.resume_len - shared_len)
                except OutOfPages:
                    self.cache.free(slot)
                    raise
                except InjectedFault:
                    # transient allocation fault: unwind this admission
                    # completely (slot freed, request back at the head of
                    # the resuming queue, still PREEMPTED with its stash
                    # intact) and stop admitting this step -- the resume
                    # simply retries next step
                    self.cache.free(slot)
                    self.resuming.appendleft(req)
                    break
                req.prefilled = req.resume_len
                req.state = RUNNING if (req.generated and req.prefill_done) \
                    else PREFILLING
            else:
                self.cache.alloc(slot)
                if shared_len:
                    self.cache.share_pages(slot, shared_pages, shared_len)
                if self.prefix_cache is not None:
                    self.prefix_cache.record_match(shared_len)
                req.prefilled = shared_len
                req.matched_len = shared_len
                req.state = PREFILLING
                promised += need
            req.slot = slot
            self.slots[slot] = req
            self._admitted_at[req.id] = self._admit_seq
            self._admit_seq += 1
            admitted.append((slot, req))
            if self.tracer is not None:
                self.tracer.on_admit(req, resumed)
        return admitted

    # -- preemption (page pressure) --------------------------------------
    def preemption_victim(self, protect: Optional[int] = None
                          ) -> Optional[int]:
        """Newest-admitted occupied slot, excluding ``protect`` (the slot
        whose growth triggered the pressure).  Newest-first keeps the
        oldest sequence always progressing -- the liveness argument."""
        cands = [(self._admitted_at[r.id], s)
                 for s, r in enumerate(self.slots)
                 if r is not None and s != protect]
        return max(cands)[1] if cands else None

    def preempt(self, slot: int) -> Request:
        """Evict the sequence in ``slot``: release its pages and park it
        on the resuming queue (kept sorted by arrival so the earliest
        submitted victim resumes first).  The caller (PressureManager)
        must have copied any KV worth keeping off the device and set
        ``resume_kind``/``resume_len`` BEFORE this call."""
        req = self.slots[slot]
        if req is None or req.state not in (PREFILLING, RUNNING):
            raise ValueError(f"slot {slot} not preemptible")
        self.cache.release_pages(slot)
        req.state = PREEMPTED
        req.slot = None
        req.preemptions += 1
        self.slots[slot] = None
        self._admitted_at.pop(req.id, None)
        idx = sum(1 for r in self.resuming if r.arrival < req.arrival)
        self.resuming.insert(idx, req)
        self.preempt_count += 1
        if self.tracer is not None:
            # resume_kind was set by the PressureManager before this call,
            # so the trace event carries the real resume strategy
            self.tracer.on_preempt(req)
        return req

    # -- abort ------------------------------------------------------------
    def abort(self, request_id: int) -> Optional[Request]:
        """Cancel a request wherever it currently lives.  Queued requests
        are simply removed; an occupied slot is freed -- shared pages
        drop one reference (never freed from under a sharer or the
        prefix index), exclusive pages return to the free list, and any
        pending copy-on-write debt whose destination page just became
        free is cancelled (the copy target may be reallocated to another
        sequence at any moment).  Returns the request, or None when the
        id is unknown/already finished.  A host-side swap stash is the
        PressureManager's to drop -- the engine core handles that."""
        for q in (self.waiting, self.resuming):
            for req in q:
                if req.id == request_id:
                    q.remove(req)
                    req.state = ABORTED
                    return req
        for slot, req in enumerate(self.slots):
            if req is None or req.id != request_id:
                continue
            pages = self.cache.owned_pages(slot)
            self.cache.free(slot)
            freed = {p for p in pages if self.cache.refcount(p) == 0}
            if freed and self.cache.cow_pending:
                self.cache.cow_pending = [
                    (s, d) for s, d in self.cache.cow_pending
                    if d not in freed]
            req.state = ABORTED
            req.slot = None
            self.slots[slot] = None
            self._admitted_at.pop(req.id, None)
            return req
        return None

    def prefill_schedule(self, budget: int,
                         chunk: int) -> List[Tuple[int, Request, int, int]]:
        """Plan this step's chunked-prefill work: ``(slot, req, start,
        n_tokens)`` jobs in admission order.  ``budget`` is a soft cap
        rounded up to whole chunks (chunks are fixed-cost launches, so
        sub-chunk budgeting buys nothing): planning stops at the first
        chunk boundary at or past it, overshooting by at most
        ``chunk - 1`` tokens.  Always emits at least one chunk when
        anything is PREFILLING (a zero/tiny budget must not starve
        prefill), and completes oldest prompts first so their first
        token streams out as early as possible."""
        jobs: List[Tuple[int, Request, int, int]] = []
        spent = 0
        for slot, req in self.prefilling():
            start = req.prefilled
            total = req.prefill_total
            while start < total:
                if jobs and spent >= budget:
                    return jobs
                n = min(chunk, total - start)
                jobs.append((slot, req, start, n))
                start += n
                spent += n
        return jobs

    # -- introspection ----------------------------------------------------
    def running(self) -> List[Tuple[int, Request]]:
        """All occupied slots (prefilling or decoding)."""
        return [(s, r) for s, r in enumerate(self.slots) if r is not None]

    def prefilling(self) -> List[Tuple[int, Request]]:
        """Slots still pushing prompt chunks, oldest admission first."""
        return sorted(
            ((s, r) for s, r in enumerate(self.slots)
             if r is not None and r.state == PREFILLING),
            key=lambda sr: self._admitted_at.get(sr[1].id, 0))

    def decoding(self) -> List[Tuple[int, Request]]:
        """Slots with a fully-prefilled sequence producing tokens."""
        return [(s, r) for s, r in enumerate(self.slots)
                if r is not None and r.state == RUNNING]

    @property
    def has_work(self) -> bool:
        return (bool(self.waiting) or bool(self.resuming)
                or any(r is not None for r in self.slots))
