"""Fault taxonomy and deterministic fault injection for the serving stack.

Production attention engines treat the serving runtime, not just the
kernel, as the deliverable: a transient swap DMA error, NaN logits from
one degenerate request, or a full waiting queue must degrade to a
*per-request* outcome -- never strand the page pool, refcounts, COW
debts or swap stashes of the co-tenants.  This module defines the two
halves of that contract:

**The error taxonomy.**  ``RequestError`` (and its subclasses) marks a
failure attributable to exactly one request; ``EngineCore.step()``
quarantines the offending request -- pages freed, shared-prefix pages
decref'd, stash dropped -- and keeps serving everyone else.
``EngineError`` marks a failure of the engine itself (an invariant
breach, an unrecoverable device error): the core surfaces it and stops,
because continuing would corrupt co-tenant state.  ``RequestRejected``
doubles as a ``ValueError`` so pre-existing callers catching submit
validation errors keep working.

**The fault injector.**  A seeded, deterministic chaos harness: named
*sites* are threaded through ``PagedKVCache`` (``page_alloc``),
``PressureManager`` (``swap_d2h``/``swap_h2d``) and ``EngineCore``
(``cow_copy``, ``prefill_launch``, ``decode_launch``, ``sample``)
behind a no-op default -- ``injector is None`` costs nothing and, since
every site fires on the host between device launches, an *armed*
injector never changes what gets traced either.  Each site carries an
independent schedule (nth-call, every-k, seeded probability, burst) so
a soak test can replay the exact same fault pattern from a seed and
assert the engine's invariants hold under it.

    inj = FaultInjector(seed=7)
    inj.arm("swap_d2h", prob=0.2)           # seeded coin per call
    inj.arm("page_alloc", nth=(3, 9))       # exactly calls 3 and 9
    inj.arm("decode_launch", burst=(5, 2))  # calls 5 and 6
    core = EngineCore(model, params, cfg, serve, injector=inj)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple
import zlib

import numpy as np

# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


class EngineError(RuntimeError):
    """The engine itself failed (invariant breach, unrecoverable device
    error): co-tenant state can no longer be trusted, so this propagates
    out of ``step()`` instead of being absorbed per request."""


class RequestError(RuntimeError):
    """A failure attributable to a single request.  ``step()`` turns it
    into a quarantine: the request reaches the terminal FAILED state
    with a structured error event; everything else keeps serving."""

    code = "internal"

    def __init__(self, message: str, *, request_id: Optional[int] = None):
        super().__init__(message)
        self.request_id = request_id

    @property
    def detail(self) -> str:
        return f"{self.code}: {self}"


class RequestRejected(RequestError, ValueError):
    """Submit-time rejection: the request can never fit the pool, or the
    bounded waiting queue is full under ``queue_policy="reject"``.
    Subclasses ValueError so existing submit-validation callers keep
    catching it."""

    code = "rejected"


class RequestTimeout(RequestError):
    """The request's ``deadline_ms`` expired -- shed from the queue or
    aborted mid-flight, depending on where the deadline caught it."""

    code = "timeout"


class LogitError(RequestError):
    """The request's logits came back non-finite (NaN/Inf) under
    ``ServeConfig.logit_guard="fail"``: only the offending request
    fails; co-batched rows are unaffected."""

    code = "logits"


class InjectedFault(RuntimeError):
    """Raised by an armed ``FaultInjector`` site.  Models a *transient*
    hardware/runtime fault: swap sites retry it with backoff, launch
    sites skip the launch and retry next step, per-request sites
    (page_alloc, cow_copy, sample) quarantine the request."""

    def __init__(self, site: str, call: int):
        super().__init__(f"injected fault at {site} (call {call})")
        self.site = site
        self.call = call


class SwapRestoreFailed(RuntimeError):
    """A swap-in (host->device restore) failed past its retry budget.
    The engine downgrades the resume to recompute instead of failing
    the request."""


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

SITES: Tuple[str, ...] = (
    "page_alloc",      # PagedKVCache.append about to take free pages / COW
    "swap_d2h",        # PressureManager gather_pages (swap-out DMA)
    "swap_h2d",        # PressureManager scatter_pages (swap-in DMA)
    "cow_copy",        # EngineCore copy-on-write replay on the device pools
    "prefill_launch",  # one chunked/scan prefill launch group
    "decode_launch",   # the fused decode step for all running slots
    "sample",          # per-request token sampling
    "spec_verify",     # speculative draft+verify step (falls back to K=0)
)


@dataclass
class _SiteSchedule:
    """When a site fires, as a pure function of its call counter (and a
    per-site seeded RNG for ``prob``) -- replaying the same calls under
    the same seed reproduces the same fire pattern exactly."""

    nth: frozenset = frozenset()          # 1-based call numbers that fire
    every: int = 0                        # fire every k-th call (k > 0)
    prob: float = 0.0                     # per-call seeded coin
    burst: Optional[Tuple[int, int]] = None   # (first_call, n_calls)
    times: int = -1                       # max total fires (-1 = unlimited)
    calls: int = 0
    fired: int = 0
    rng: Optional[np.random.Generator] = field(default=None, repr=False)

    def should_fire(self) -> bool:
        self.calls += 1
        if 0 <= self.times <= self.fired:
            return False
        hit = (self.calls in self.nth
               or (self.every and self.calls % self.every == 0)
               or (self.burst is not None
                   and self.burst[0] <= self.calls
                   < self.burst[0] + self.burst[1]))
        # the coin is tossed on every call (not just misses) so the fire
        # pattern depends only on the call count, never on which other
        # trigger matched first
        if self.prob > 0.0 and self.rng is not None:
            hit = bool(self.rng.random() < self.prob) or hit
        if hit:
            self.fired += 1
        return hit


class FaultInjector:
    """Seeded, deterministic fault injector over the named ``SITES``.

    ``fire(site)`` increments the site's call counter and raises
    ``InjectedFault`` when the site's schedule says so.  An un-armed
    site never fires, so a default-constructed injector is a pure
    counter (the zero-overhead / trace-neutrality contract is tested).
    ``fired_log`` records every (site, call#) that fired -- two
    injectors with equal seeds and schedules replaying the same call
    sequence produce equal logs.
    """

    SITES = SITES

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._sched: Dict[str, _SiteSchedule] = {}
        self._calls: Dict[str, int] = {s: 0 for s in SITES}
        self.fired_log: List[Tuple[str, int]] = []

    @staticmethod
    def _check_site(site: str) -> None:
        if site not in SITES:
            raise ValueError(
                f"unknown fault site {site!r}; sites: {', '.join(SITES)}")

    def arm(self, site: str, *, nth: Tuple[int, ...] = (), every: int = 0,
            prob: float = 0.0, burst: Optional[Tuple[int, int]] = None,
            times: int = -1) -> "FaultInjector":
        """Arm ``site`` with a schedule.  Triggers compose (a call fires
        when any matches); ``times`` caps total fires.  Returns self so
        arms chain.  The per-site RNG seed folds the site name into the
        injector seed, so distinct sites draw independent streams and
        the whole pattern is reproducible from ``seed`` alone."""
        self._check_site(site)
        if every < 0 or prob < 0.0 or prob > 1.0:
            raise ValueError(f"bad schedule for {site}: every={every} "
                             f"prob={prob}")
        if burst is not None and (burst[0] < 1 or burst[1] < 1):
            raise ValueError(f"burst must be (first_call>=1, n>=1), "
                             f"got {burst}")
        rng = (np.random.default_rng(
            (self.seed & 0xFFFFFFFF) ^ zlib.crc32(site.encode()))
            if prob > 0.0 else None)
        self._sched[site] = _SiteSchedule(
            nth=frozenset(int(n) for n in nth), every=every, prob=prob,
            burst=burst, times=times, rng=rng)
        return self

    def fire(self, site: str) -> None:
        """Count a pass through ``site``; raise InjectedFault when its
        schedule triggers.  Sites are host-side only -- this must never
        be called from inside a traced function."""
        self._check_site(site)
        self._calls[site] += 1
        sched = self._sched.get(site)
        if sched is None:
            return
        if sched.should_fire():
            self.fired_log.append((site, self._calls[site]))
            raise InjectedFault(site, self._calls[site])

    def calls(self, site: str) -> int:
        self._check_site(site)
        return self._calls[site]

    @property
    def total_fired(self) -> int:
        return len(self.fired_log)

    def stats(self) -> dict:
        return {"calls": dict(self._calls),
                "fired": len(self.fired_log),
                "armed": sorted(self._sched)}
