"""Engine telemetry: metrics registry, lifecycle tracing, flight recorder.

Production serving engines treat observability as a subsystem, not a
stats dict: vLLM exports Prometheus counters and per-request latency
histograms, FlashInfer feeds per-kernel attention telemetry back into
its scheduler, and the source FastAttention paper motivates both its
tiling-AllReduce and its CPU-GPU cooperative strategy with exactly the
per-phase time/bandwidth breakdowns an uninstrumented engine cannot
produce.  This module is that subsystem for the EngineCore stack --
dependency-free, host-side only (nothing here is ever traced by jit, so
telemetry can never change trace counts), and O(1) on the hot path:

* :class:`MetricsRegistry` -- named :class:`Counter`/:class:`Gauge`/
  fixed-bucket :class:`Histogram` metrics with *windowed* reads:
  cumulative totals survive for Prometheus exposition
  (:meth:`~MetricsRegistry.to_prometheus`), while ``snapshot(reset=True)``
  / :meth:`~MetricsRegistry.reset_window` give bench-style "cover only
  the timed region" semantics.  ``EngineCore.stats()`` keeps its shape
  but reads these windows.

* :class:`LifecycleTracer` -- per-request span events on the engine's
  injectable clock (submitted -> queued -> prefilling -> first-token ->
  running -> preempted/swapped/resumed -> finished/failed/shed), turning
  TTFT, TPOT, queue delay and preemption stalls into engine-native
  histograms instead of bench-side arithmetic.  Every opened span is
  closed by a terminal event (finish/fail/abort), asserted under the
  chaos soak; ``completed`` keeps a bounded log of per-request latency
  records for exact engine-vs-bench comparisons.

* :class:`FlightRecorder` -- a bounded ring buffer of per-step records
  (phase timings, batch composition, pages used, faults fired) the
  engine dumps on ``EngineError``/quarantine and exports as a Chrome
  ``trace_event`` JSON timeline (chrome://tracing / Perfetto) for
  postmortems.
"""
from __future__ import annotations

from bisect import bisect_left
from collections import deque
from typing import Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LifecycleTracer", "FlightRecorder", "DEFAULT_TIME_BUCKETS"]

# Upper bucket bounds (seconds, ``le``-inclusive like Prometheus) for
# the latency histograms: 100us .. 60s, roughly log-spaced.  Chosen to
# straddle both the smoke model's ~1ms steps and real-hardware TTFTs.
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _fmt(v) -> str:
    """Prometheus sample formatting: integers stay integral."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return format(float(v), ".10g")


class Counter:
    """Monotonic counter with a windowed view.  ``value`` is the
    cumulative total (Prometheus semantics: only resets with the
    registry); ``window`` counts since the last window reset -- what
    ``stats()`` and the benches report."""

    __slots__ = ("name", "help", "total", "_base")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.total = 0
        self._base = 0

    def inc(self, n: int = 1) -> None:
        self.total += n

    @property
    def value(self):
        return self.total

    @property
    def window(self):
        return self.total - self._base

    def reset_window(self) -> None:
        self._base = self.total

    def snapshot(self) -> dict:
        return {"type": "counter", "total": self.total,
                "window": self.window}


class Gauge:
    """Point-in-time value.  ``high_water=True`` makes ``set`` keep the
    window maximum instead of the last value (peak pages, slowest
    step); a window reset re-arms it at 0."""

    __slots__ = ("name", "help", "high_water", "value")

    def __init__(self, name: str, help: str = "", *,
                 high_water: bool = False):
        self.name = name
        self.help = help
        self.high_water = high_water
        self.value = 0.0

    def set(self, v) -> None:
        self.value = max(self.value, v) if self.high_water else v

    def reset_window(self) -> None:
        if self.high_water:
            self.value = 0.0

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram with O(1) (``O(log n_buckets)``)
    recording.  ``buckets`` are upper bounds, ``le``-inclusive exactly
    like Prometheus (an observation equal to an edge lands in that
    edge's bucket); everything above the last edge lands in ``+Inf``.
    The whole histogram is windowed -- ``reset_window`` clears it -- and
    the cumulative total is kept separately for exposition."""

    __slots__ = ("name", "help", "edges", "counts", "count", "sum",
                 "window_min", "window_max", "total_count", "total_sum")

    def __init__(self, name: str, buckets=DEFAULT_TIME_BUCKETS,
                 help: str = ""):
        edges = tuple(sorted(float(b) for b in buckets))
        if not edges:
            raise ValueError(f"histogram {name}: need >= 1 bucket edge")
        self.name = name
        self.help = help
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)     # last = +Inf
        self.count = 0
        self.sum = 0.0
        self.window_min = float("inf")
        self.window_max = 0.0
        self.total_count = 0
        self.total_sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        self.total_count += 1
        self.total_sum += v
        if v < self.window_min:
            self.window_min = v
        if v > self.window_max:
            self.window_max = v

    def reset_window(self) -> None:
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.window_min = float("inf")
        self.window_max = 0.0

    def percentile(self, q: float) -> float:
        """Bucketed quantile over the window: the smallest bucket edge
        whose cumulative count covers ``q`` (0..100).  Coarse by design
        -- exact per-request latencies live on ``LifecycleTracer.
        completed``; this answers "which latency band" questions."""
        if not self.count:
            return 0.0
        target = (q / 100.0) * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                return (self.edges[i] if i < len(self.edges)
                        else self.window_max)
        return self.window_max

    def snapshot(self) -> dict:
        buckets = {}
        cum = 0
        for i, edge in enumerate(self.edges):
            cum += self.counts[i]
            buckets[edge] = cum
        return {"type": "histogram", "count": self.count,
                "sum": self.sum, "max": self.window_max,
                "min": 0.0 if self.count == 0 else self.window_min,
                "buckets": buckets}


class MetricsRegistry:
    """Named metrics with get-or-create accessors, windowed snapshots
    and Prometheus/JSON exposition.  Creation validates the kind: one
    name is forever one metric type."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, "
                f"not a {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "", *,
              high_water: bool = False) -> Gauge:
        return self._get(name, Gauge, help=help, high_water=high_water)

    def histogram(self, name: str, buckets=DEFAULT_TIME_BUCKETS,
                  help: str = "") -> Histogram:
        return self._get(name, Histogram, buckets=buckets, help=help)

    # -- hot-path conveniences (resolve by name once, then hold the
    # returned object: the bound-attribute path is the O(1) contract) --
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # -- windows -------------------------------------------------------
    def reset_window(self) -> None:
        """Open a fresh measurement window: counters keep their
        cumulative totals but ``window`` restarts at 0, histograms and
        high-water gauges clear.  The bench warmup calls this so the
        reported metrics cover only the timed workload."""
        for m in self._metrics.values():
            m.reset_window()

    def snapshot(self, reset: bool = False) -> dict:
        """Windowed view of every metric (plain dicts, JSON-safe).
        ``reset=True`` atomically opens the next window -- successive
        snapshots then partition time, Prometheus-scrape style."""
        out = {name: self._metrics[name].snapshot()
               for name in sorted(self._metrics)}
        if reset:
            self.reset_window()
        return out

    # -- exposition ----------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4) over *cumulative*
        values -- scrapers do their own windowing via rate()."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {_fmt(m.total)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {_fmt(m.value)}")
            else:
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for i, edge in enumerate(m.edges):
                    cum += m.counts[i]
                    lines.append(
                        f'{name}_bucket{{le="{_fmt(edge)}"}} {cum}')
                # window counts roll into the totals at reset, so +Inf
                # must come from the cumulative track to stay monotonic
                lines.append(
                    f'{name}_bucket{{le="+Inf"}} {m.total_count}')
                lines.append(f"{name}_sum {_fmt(m.total_sum)}")
                lines.append(f"{name}_count {m.total_count}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        return self.snapshot()


# ---------------------------------------------------------------------------
# per-request lifecycle tracing
# ---------------------------------------------------------------------------

class LifecycleTracer:
    """Span accounting for every request on the engine's injectable
    clock.  The engine/scheduler/pressure hooks call the ``on_*``
    methods at state transitions; each appends a timestamped event to
    ``Request.trace`` and maintains *open spans* per request:

    ``queued``    submit -> first admission          (queue delay)
    ``prefill``   admission -> first token           (prefill residency)
    ``running``   first token -> terminal            (decode residency)
    ``preempted`` eviction -> re-admission           (preemption stall)
    ``swapped``   swap-out -> restore/drop           (host-stash residency)

    A terminal transition (finished/failed/shed/timed-out/aborted)
    closes every open span, so ``open_span_count() == 0`` after drain is
    an invariant the chaos soak asserts.  Closed spans feed the
    engine-native latency histograms; ``completed`` keeps a bounded log
    of exact per-request records (submit/first/last token timestamps)
    so benches can compare engine-native TTFT/TPOT against their own
    arithmetic without bucket quantisation."""

    COMPLETED_LOG = 4096

    def __init__(self, registry: MetricsRegistry, clock):
        self.m = registry
        self.clock = clock
        self.open: Dict[int, Dict[str, float]] = {}   # rid -> span -> t0
        self._live: Dict[int, dict] = {}              # rid -> record
        self.completed: deque = deque(maxlen=self.COMPLETED_LOG)  # repro-lint: disable=silent-drop (bounded span log; histograms keep the totals)
        h = registry.histogram
        self._h_queue = h("engine_queue_delay_seconds",
                          help="submit to first admission")
        self._h_ttft = h("engine_ttft_seconds",
                         help="submit to first streamed token")
        self._h_tpot = h("engine_tpot_seconds",
                         help="mean gap between a request's tokens")
        self._h_e2e = h("engine_e2e_seconds",
                        help="submit to terminal event")
        self._h_stall = h("engine_preempt_stall_seconds",
                          help="eviction to re-admission")

    # -- bookkeeping helpers -------------------------------------------
    def _mark(self, req, event: str, t: float) -> None:
        req.trace.append((event, t))

    def _open(self, rid: int, span: str, t: float) -> None:
        self.open.setdefault(rid, {}).setdefault(span, t)

    def _close(self, rid: int, span: str, t: float) -> Optional[float]:
        spans = self.open.get(rid)
        if spans is None or span not in spans:
            return None
        dt = t - spans.pop(span)
        if not spans:
            del self.open[rid]
        return dt

    def open_span_count(self) -> int:
        return sum(len(s) for s in self.open.values())

    def reset(self) -> None:
        """Engine state reset: every request is gone, so open spans and
        live records go with it; the completed log and the histograms
        persist (clear those with the registry window)."""
        self.open.clear()
        self._live.clear()

    def clear_completed(self) -> None:
        self.completed.clear()

    # -- transitions ---------------------------------------------------
    def on_submit(self, req) -> None:
        t = self.clock()
        self._mark(req, "submitted", t)
        self._open(req.id, "queued", t)
        self._live[req.id] = {"id": req.id, "submit_t": t,
                              "first_token_t": None, "last_token_t": None,
                              "n_tokens": 0, "preemptions": 0}
        self.m.inc("engine_requests_submitted_total")

    def on_admit(self, req, resumed: bool) -> None:
        t = self.clock()
        rec = self._live.get(req.id)
        if resumed:
            self._mark(req, "resumed", t)
            dt = self._close(req.id, "preempted", t)
            if dt is not None:
                self._h_stall.observe(dt)
        else:
            self._mark(req, "prefilling", t)
            dt = self._close(req.id, "queued", t)
            if dt is not None:
                self._h_queue.observe(dt)
        # a decode-resumed sequence goes straight back to running; a
        # fresh or recompute-resumed one re-enters the prefill span
        if rec is None or rec["first_token_t"] is None or not resumed:
            self._open(req.id, "prefill", t)

    def on_first_token(self, req) -> None:
        t = self.clock()
        self._mark(req, "first-token", t)
        self._close(req.id, "prefill", t)
        self._open(req.id, "running", t)
        rec = self._live.get(req.id)
        if rec is not None and rec["first_token_t"] is None:
            rec["first_token_t"] = t
            self._h_ttft.observe(t - rec["submit_t"])

    def on_token(self, req) -> None:
        rec = self._live.get(req.id)
        if rec is not None:
            rec["last_token_t"] = self.clock()
            rec["n_tokens"] += 1

    def on_preempt(self, req) -> None:
        t = self.clock()
        self._mark(req, f"preempted:{req.resume_kind}", t)
        # whichever residency span was open pauses here; the preempted
        # span measures the stall until re-admission
        self._close(req.id, "prefill", t)
        self._close(req.id, "running", t)
        self._open(req.id, "preempted", t)
        rec = self._live.get(req.id)
        if rec is not None:
            rec["preemptions"] += 1

    def on_swap_out(self, req) -> None:
        self._open(req.id, "swapped", self.clock())

    def on_swap_in(self, req) -> None:
        self._close(req.id, "swapped", self.clock())

    def on_swap_drop(self, rid: int) -> None:
        self._close(rid, "swapped", self.clock())

    # -- terminals (close everything, always) --------------------------
    def _finish(self, req, reason: str) -> None:
        t = self.clock()
        self._mark(req, reason, t)
        self.open.pop(req.id, None)
        rec = self._live.pop(req.id, None)
        if rec is None:
            return
        rec["end_t"] = t
        rec["reason"] = reason
        self._h_e2e.observe(t - rec["submit_t"])
        if reason == "finished" and rec["n_tokens"] > 1:
            rec["tpot_s"] = ((rec["last_token_t"] - rec["first_token_t"])
                             / (rec["n_tokens"] - 1))
            self._h_tpot.observe(rec["tpot_s"])
        self.completed.append(rec)

    def on_retire(self, req) -> None:
        self._finish(req, "finished")
        self.m.inc("engine_requests_finished_total")

    def on_fail(self, req, code: str) -> None:
        self._finish(req, code)          # "failed" | "shed" | "timed_out"

    def on_abort(self, req) -> None:
        self._finish(req, "aborted")


# ---------------------------------------------------------------------------
# step flight recorder
# ---------------------------------------------------------------------------

class FlightRecorder:
    """Bounded ring buffer of per-step records for postmortems: what
    was the engine doing in the N steps before the EngineError?  Each
    record is a plain dict (step index, engine-clock start, duration,
    per-phase seconds, batch composition, pages used, faults fired,
    quarantines) so a dump is directly JSON-serialisable, and
    :meth:`to_chrome_trace` renders a dump as a Chrome ``trace_event``
    timeline (load in chrome://tracing or https://ui.perfetto.dev)."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"flight recorder needs capacity >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        self.records: deque = deque(maxlen=capacity)  # repro-lint: disable=silent-drop (flight ring: overwrite-oldest is the point)
        self.dumps = 0

    def record(self, rec: dict) -> None:
        self.records.append(rec)

    def dump(self) -> List[dict]:
        self.dumps += 1
        return list(self.records)

    def to_chrome_trace(self, records: Optional[List[dict]] = None) -> dict:
        """Chrome ``trace_event`` JSON for a dump (default: the live
        buffer, without counting a dump).  Steps are complete ("X")
        events on tid 0, their phase breakdown laid out sequentially on
        tid 1 (phase *durations* are exact; their offsets within the
        step are reconstructed in recorded order), and quarantines /
        errors are instant ("i") events."""
        if records is None:
            records = list(self.records)
        events: List[dict] = []
        pid = 0
        for rec in records:
            ts = rec["t_start"] * 1e6            # trace_event wants us
            dur = max(rec.get("dur_s", 0.0), 0.0) * 1e6
            args = {k: rec[k] for k in
                    ("waiting", "resuming", "prefilling", "decoding",
                     "pages_used", "events", "faults_fired")
                    if k in rec}
            events.append({"name": f"step {rec['step']}", "ph": "X",
                           "ts": ts, "dur": dur, "pid": pid, "tid": 0,
                           "cat": "step", "args": args})
            off = ts
            for phase, dt in rec.get("phases", {}).items():
                pdur = max(dt, 0.0) * 1e6
                events.append({"name": phase, "ph": "X", "ts": off,
                               "dur": pdur, "pid": pid, "tid": 1,
                               "cat": "phase"})
                off += pdur
            for detail in rec.get("quarantined", ()):
                events.append({"name": "quarantine", "ph": "i", "ts": ts,
                               "pid": pid, "tid": 0, "s": "t",
                               "cat": "fault", "args": {"detail": detail}})
            if rec.get("error"):
                events.append({"name": "engine-error", "ph": "i",
                               "ts": ts + dur, "pid": pid, "tid": 0,
                               "s": "t", "cat": "fault",
                               "args": {"detail": rec["error"]}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}
