"""EngineCore: the persistent, iteration-level serving engine.

vLLM's ``LLMEngine.add_request``/``step`` and FlashInfer's decoupled
plan/run interface expose the same shape: a *core* that owns all serving
state -- page manager, scheduler, pressure manager, radix prefix index,
device page pools, jitted paged functions -- and advances the whole
system exactly one iteration per ``step()`` call.  Frontends, arrival
processes and multi-tenant policies then compose on top, and features
that need a step boundary to hook into (overlapped swap, multi-host
decode) have one.

    core = EngineCore(model=model, params=params, cfg=cfg, serve=serve)
    rid = core.add_request(prompt, SamplingParams(max_new_tokens=32))
    while core.has_work:
        for ev in core.step():          # list[StreamEvent], may be empty
            ...
    core.abort(rid)                     # any time: frees pages, no leaks

Everything persists across requests unconditionally -- the prefix-cache-
only ``_shared_state`` special case of the previous ``ServeEngine`` is
gone: abandoning a stream is now a plain ``abort()`` (free the slot's
pages, cancel its copy-on-write debts, drop any swap stash; shared
prefix pages just lose one reference).

Sampling is per request (``SamplingParams``) with a counter-based RNG:
the key for a request's n-th sampled token is
``fold_in(PRNGKey(params.seed), n)``, so sampled tokens are invariant to
batch composition, co-tenants, preemption and admission order.  The
engine-global ``ServeConfig.temperature/top_k`` knobs survive only as
deprecated defaults for requests submitted without params.

``ServeEngine.generate_stream`` is a thin compatibility wrapper over
this class (submit, drain ``step()``, abort leftovers on close) -- its
greedy output is bit-identical to the pre-core engine.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core.fastattention import default_paged_impl
from repro.serving.faults import (EngineError, InjectedFault, LogitError,
                                  RequestError, RequestRejected,
                                  RequestTimeout, SwapRestoreFailed)
from repro.serving.metrics import (FlightRecorder, LifecycleTracer,
                                   MetricsRegistry)
from repro.serving.paged_cache import OutOfPages, PagedKVCache
from repro.serving.prefix_cache import RadixPrefixIndex
from repro.serving.pressure import PressureManager, copy_pages
from repro.serving.scheduler import (ABORTED, FAILED, FINISHED, PREFILLING,
                                     RUNNING, ContinuousBatchScheduler,
                                     Request, SamplingParams)
from repro.serving.spec import (PromptLookupDrafter, verify_greedy,
                                verify_residual)
from repro.sharding.tp import plan_tp, tp_context


def sample_token(logits, key, *, temperature: float = 1.0, top_k: int = 0):
    if temperature == 0.0 or top_k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 1:
        # lax.top_k rejects k > vocab; clamping makes oversized k mean
        # "no truncation" instead of a crash
        k = min(top_k, lf.shape[-1])
        vals, _ = jax.lax.top_k(lf, k)
        thresh = vals[..., -1:]
        lf = jnp.where(lf < thresh, -1e30, lf)
    return jax.random.categorical(key, lf).astype(jnp.int32)


class StreamEvent(NamedTuple):
    """One stream event.  ``kind="token"`` (the default, and the only
    kind before the fault-tolerance layer) carries one generated token,
    emitted the step it exists.  ``kind="stop"`` terminates a
    stop-string request whose matched suffix was trimmed (token is -1).
    ``kind="error"`` terminates a FAILED/shed/timed-out request with the
    structured ``detail`` ("code: message") and token -1."""
    request_id: int
    token: int
    index: int            # position within the request's generation
    finished: bool        # True on the request's last event
    kind: str = "token"
    detail: str = ""


class _CountingDeque(deque):
    """Bounded deque that counts evictions instead of losing them
    silently: a full ``append`` still drops the oldest entry (the bound
    is the point), but ``dropped`` records how many orphaned events were
    lost so ``stats()`` can surface the loss."""

    def __init__(self, maxlen: int):
        super().__init__(maxlen=maxlen)
        self.dropped = 0

    def append(self, item) -> None:
        if self.maxlen is not None and len(self) == self.maxlen:
            self.dropped += 1
        super().append(item)


class EngineCore:
    """Persistent iteration-level engine over the paged KV cache.

    One ``step()`` = retire finished sequences, admit waiting/resuming
    requests, spend the prefill token budget on chunked prompt prefill,
    run one fused decode step for every RUNNING slot, and return the
    tokens produced.  All state lives on the core and survives between
    calls -- including the device page pools, so prefix-cache hits keep
    their KV across requests.
    """

    def __init__(self, model, params, cfg: ModelConfig,
                 serve: Optional[ServeConfig] = None, *,
                 fn_cache: Optional[dict] = None, injector=None,
                 detokenize=None, clock=None, metrics=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.serve = serve or ServeConfig()
        if self.serve.logit_guard not in ("fail", "ignore"):
            raise ValueError(
                f"unknown logit_guard {self.serve.logit_guard!r}")
        if self.serve.queue_policy not in ("reject", "shed_oldest"):
            raise ValueError(
                f"unknown queue_policy {self.serve.queue_policy!r}")
        if self.serve.spec_mode not in ("off", "lookup"):
            raise ValueError(
                f"unknown spec_mode {self.serve.spec_mode!r}")
        if self.serve.spec_mode != "off" and self.serve.spec_tokens < 1:
            raise ValueError(
                f"spec_tokens must be >= 1 with spec_mode="
                f"{self.serve.spec_mode!r}, got {self.serve.spec_tokens}")
        # fault-injection harness (serving/faults.py): threaded through
        # the page manager and pressure manager; None costs nothing
        self.injector = injector
        # token ids -> text, required only by SamplingParams.stop_strings
        self.detokenize = detokenize
        # engine clock (seconds, monotonic) for deadlines AND all engine
        # timing (step watchdog, spans, phase breakdown); injectable so
        # fake-clock tests observe every timing path deterministically
        self._clock = clock or time.monotonic
        # -- telemetry (serving/metrics.py) ----------------------------
        # The registry is always live: its counters back the ``stats()``
        # view (a handful of integer adds per step).  The lifecycle
        # tracer, per-step phase breakdown and flight recorder gate on
        # ``serve.metrics`` -- they are the clock-read overhead.  All of
        # it is host-side between launches: trace-neutral by
        # construction, asserted in tests/test_metrics.py.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._c_steps = m.counter("engine_steps_total",
                                  help="engine step() iterations")
        self._c_events = m.counter("engine_events_total",
                                   help="stream events emitted")
        self._c_aborts = m.counter("engine_requests_aborted_total",
                                   help="caller aborts")
        self._c_failed = m.counter("engine_requests_failed_total",
                                   help="requests quarantined "
                                        "(internal/logits/injected)")
        self._c_shed = m.counter("engine_requests_shed_total",
                                 help="requests shed from the bounded "
                                      "waiting queue")
        self._c_timeout = m.counter("engine_requests_timed_out_total",
                                    help="deadline_ms expiries")
        # speculative decoding (serving/spec.py): drafted/accepted token
        # counters plus accept-rate and accepted-run-length histograms;
        # created unconditionally (a handful of registry entries) but
        # only touched when spec_mode != "off"
        self._c_spec_drafted = m.counter(
            "engine_spec_drafted_total",
            help="speculative tokens drafted for verification")
        self._c_spec_accepted = m.counter(
            "engine_spec_accepted_total",
            help="drafted tokens accepted by verification")
        self._h_spec_accept = m.histogram(
            "engine_spec_accept_rate",
            buckets=(0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875,
                     1.0),
            help="per-request accept rate per verify step")
        self._h_spec_run = m.histogram(
            "engine_spec_run_length",
            buckets=tuple(float(i)
                          for i in range(self.serve.spec_tokens + 1)),
            help="accepted draft run length per verify step")
        self._h_step = m.histogram("engine_step_seconds",
                                   help="step() wall-clock on the "
                                        "engine clock")
        self._g_pages = m.gauge("kv_pages_used",
                                help="physical KV pages in use")
        self._g_pages_hw = m.gauge("kv_pages_peak", high_water=True,
                                   help="peak KV pages in use "
                                        "(current window)")
        self.tracer = (LifecycleTracer(m, self._clock)
                       if self.serve.metrics else None)
        self.flight = (FlightRecorder(self.serve.flight_recorder_steps)
                       if self.serve.metrics else None)
        # most recent flight-recorder dump: taken when an EngineError
        # propagates out of step() or a request is quarantined, so the
        # postmortem survives on the core even if the caller only sees
        # the exception (which also carries it as ``.flight``)
        self.last_flight_dump: Optional[List[dict]] = None
        self._step_rec: Optional[dict] = None
        self._dump_pending = False
        # tensor parallelism (sharding/tp.py): factor serve.tp into
        # kv-head groups x page-row sub-shards and bind a 2-D mesh; the
        # paged forward fns trace under tp_context, flipping the
        # attention/MLP layers onto their shard_map TP bodies
        self.tp_plan = None
        self.tp_mesh = None
        if self.serve.tp > 1:
            from repro.launch.mesh import make_mesh
            plan = plan_tp(cfg, self.serve.tp, self.serve.page_size,
                           collectives=self.serve.tp_collectives,
                           ar_chunks=self.serve.tp_ar_chunks,
                           first_chunk_frac=self.serve.tp_first_chunk_frac)
            if jax.device_count() < plan.tp:
                raise ValueError(
                    f"tp={plan.tp} needs {plan.tp} devices, "
                    f"found {jax.device_count()}")
            self.tp_plan = plan
            self.tp_mesh = make_mesh(plan.mesh_shape, plan.axes)
        # jitted paged prefill/decode triples keyed by (resolved impl,
        # tp plan); shared with the ServeEngine wrapper so clearing one
        # clears both
        self._paged_fn_cache = fn_cache if fn_cache is not None else {}
        # how many times the chunked-prefill function was *traced* (not
        # called): the trace-count test asserts it stays bounded by
        # launch widths no matter how many prompt lengths stream through
        self.prefill_trace_count = 0
        # prefill chunk *launches* (calls, not traces): prefix-cache hits
        # skip the matched prefix's launches entirely, asserted in tests
        self.prefill_launches = 0
        # speculative verify launches/traces, counted apart from prefill
        # so the prefill trace/launch assertions hold with spec on, and
        # spec_mode="off" provably never touches the verify fn
        self.spec_launches = 0
        self.spec_trace_count = 0
        self._warned_legacy_sampling = False
        self._next_id = 0
        self.reset()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Drop every request, page, stash and cached prefix and rebuild
        the serving state from ``self.serve``.  Jit caches, trace
        counters and the metrics registry survive (they are keyed by the
        engine's lifetime, not its state) -- use
        ``reset_metrics_window()`` to open a fresh measurement window."""
        serve = self.serve
        self.mgr = PagedKVCache(serve.pool_pages(), serve.page_size,
                                serve.max_batch, serve.max_pages_per_seq,
                                injector=self.injector,
                                metrics=self.metrics)
        self.prefix = (RadixPrefixIndex(self.mgr, serve.page_size,
                                        serve.prefix_cache_pages,
                                        metrics=self.metrics)
                       if serve.prefix_cache else None)
        self.sched = ContinuousBatchScheduler(
            self.mgr, serve.max_batch, admission=serve.admission,
            watermark_pages=serve.watermark, prefix_cache=self.prefix,
            tracer=self.tracer)
        self.pressure = PressureManager(self.cfg, serve, self.mgr,
                                        self.sched,
                                        prefix_cache=self.prefix,
                                        injector=self.injector,
                                        metrics=self.metrics,
                                        tracer=self.tracer)
        if self.tracer is not None:
            self.tracer.reset()        # every request is gone with the state
        # speculation drafter (serving/spec.py): per-request n-gram
        # indexes and accept-rate EMAs die with the requests on reset.
        # ``spec is None`` IS the off switch -- the decode phase branches
        # on it once per step and the off path stays byte-for-byte the
        # plain decode step.
        self.spec = (PromptLookupDrafter(
            max_tokens=serve.spec_tokens,
            ngram_max=serve.spec_ngram_max,
            ngram_min=serve.spec_ngram_min,
            ema_alpha=serve.spec_ema_alpha)
            if serve.spec_mode == "lookup" else None)
        self.pools = None              # device pools, materialised lazily
        self.next_tok = np.zeros((serve.max_batch,), np.int32)
        self.requests: Dict[int, Request] = {}     # live (unfinished) only
        # events a generate_stream drain stepped out for requests no
        # drain owns (direct add_request users): step() hands each event
        # to exactly one caller, so mixed-mode users recover them here
        # (drops past the bound are counted, see stats()["orphans_dropped"])
        self.orphan_events: _CountingDeque = _CountingDeque(maxlen=4096)
        # -- fault-tolerance state -------------------------------------
        # terminal error events produced outside a step() (queue
        # shedding at submit time): the next step() returns them first
        self._pending_events: List[StreamEvent] = []
        # per-request incremental detokenisation state for stop_strings:
        # id -> {"text": decoded generation, "ends": char offset at the
        # end of each generated token}
        self._stop_state: Dict[int, dict] = {}
        self.last_error: Optional[str] = None

    # ------------------------------------------------------------------
    # registry-backed counters
    # ------------------------------------------------------------------
    # stats() is a *view* over the metrics registry: each attribute the
    # pre-telemetry engine kept as a plain int is now a read-only
    # property over the registry's current window.  Cumulative Prometheus
    # totals survive reset(); reset_metrics_window() is what opens a
    # fresh measurement window (bench warmups call it).
    @property
    def steps(self) -> int:
        return self._c_steps.window

    @property
    def events_emitted(self) -> int:
        return self._c_events.window

    @property
    def aborts(self) -> int:
        return self._c_aborts.window

    @property
    def failed_count(self) -> int:
        return self._c_failed.window

    @property
    def shed_count(self) -> int:
        return self._c_shed.window

    @property
    def timed_out_count(self) -> int:
        return self._c_timeout.window

    @property
    def step_s_high_water(self) -> float:
        return self._h_step.window_max

    def reset_metrics_window(self) -> None:
        """Open a fresh measurement window: zero every windowed counter,
        histogram and high-water gauge in the registry (cumulative
        Prometheus ``_total`` values are untouched), clear the tracer's
        completed-request log and the flight recorder's ring.  Bench
        warmups call this so the timed region starts from zero."""
        self.metrics.reset_window()
        if self.tracer is not None:
            self.tracer.clear_completed()
        if self.flight is not None:
            self.flight.records.clear()
        self.mgr.reset_peak()

    def export_prometheus(self) -> str:
        """Prometheus text-format (0.0.4) exposition of the registry."""
        return self.metrics.to_prometheus()

    def chrome_trace(self, records: Optional[List[dict]] = None) -> dict:
        """Chrome ``trace_event`` JSON for the flight recorder's current
        ring (or a prior ``dump()``): load the result into
        chrome://tracing or Perfetto for a step/phase timeline."""
        if self.flight is None:
            return {"traceEvents": [], "displayTimeUnit": "ms"}
        return self.flight.to_chrome_trace(records)

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    def stats(self) -> dict:
        """Point-in-time engine statistics (live objects, not a log)."""
        mgr, sched = self.mgr, self.sched
        out = {
            "steps": self.steps,
            "events_emitted": self.events_emitted,
            "aborts": self.aborts,
            "waiting": len(sched.waiting),
            "resuming": len(sched.resuming),
            "active_slots": sum(1 for r in sched.slots if r is not None),
            "finished": sched.finished_count,
            "pages_used": mgr.used_pages,
            "pages_free": mgr.free_pages,
            "pages_peak": mgr.peak_used_pages,
            "peak_utilization": mgr.peak_utilization,
            "prefill_launches": self.prefill_launches,
            "prefill_trace_count": self.prefill_trace_count,
            "orphan_events_pending": len(self.orphan_events),
            "orphans_dropped": self.orphan_events.dropped,
            "pressure": dict(self.pressure.stats),
            "host_pool_pages": self.pressure.host_pool.used_pages,
            "health": {
                "failed": self.failed_count,
                "shed": self.shed_count,
                "timed_out": self.timed_out_count,
                "swap_retries": self.pressure.stats["swap_retries"],
                "swap_fail_downgrades":
                    self.pressure.stats["swap_fail_downgrades"],
                "last_error": self.last_error,
                "step_s_high_water": self.step_s_high_water,
            },
        }
        if self.spec is not None:
            drafted = self._c_spec_drafted.window
            accepted = self._c_spec_accepted.window
            out["spec"] = {
                "drafted": drafted,
                "accepted": accepted,
                "accept_rate": (accepted / drafted) if drafted else 0.0,
                "verify_launches": self.spec_launches,
                "verify_trace_count": self.spec_trace_count,
            }
        if self.injector is not None:
            out["injected_faults"] = self.injector.stats()
        if self.prefix is not None:
            out["prefix"] = dict(self.prefix.stats)
            out["prefix_cached_pages"] = self.prefix.cached_pages
        if self.tp_plan is not None:
            out["tp"] = {"tp": self.tp_plan.tp, "g": self.tp_plan.g,
                         "s": self.tp_plan.s,
                         "collectives": self.tp_plan.collectives}
        return out

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def _resolve_sampling(self, req: Request, seed_offset: int = 0) -> None:
        """Give a params-less request its SamplingParams from the
        deprecated engine-global knobs (warning once per core when they
        were actually changed from their defaults).  The legacy seed
        folds in the request id so co-scheduled legacy requests do not
        sample identical streams."""
        if req.sampling is not None:
            return
        serve = self.serve
        if serve.sampling_overridden and not self._warned_legacy_sampling:
            self._warned_legacy_sampling = True
            warnings.warn(
                "engine-global ServeConfig.temperature/top_k are "
                "deprecated: pass SamplingParams per request "
                "(Request(sampling=...) or EngineCore.add_request)",
                DeprecationWarning, stacklevel=4)
        req.sampling = SamplingParams(
            temperature=serve.temperature, top_k=serve.top_k,
            seed=serve.seed + seed_offset + int(req.id),
            max_new_tokens=req.max_new_tokens,
            stop_token_ids=(req.eos_id,) if req.eos_id is not None else ())

    def submit_request(self, req: Request, *, seed_offset: int = 0
                       ) -> Request:
        """Validate and enqueue a pre-built ``Request`` (the
        generate_stream compatibility path).  Raises ``RequestRejected``
        (a ValueError) when the request can never fit the pool, needs a
        missing detokenizer for its stop_strings, or the bounded waiting
        queue is full under ``queue_policy="reject"``; plain ValueError
        when its id collides with a live request.  Under
        ``queue_policy="shed_oldest"`` a full queue sheds its oldest
        waiting request instead (structured error event on the next
        step)."""
        live = self.requests.get(req.id)
        if live is not None and live.state not in (FINISHED, ABORTED,
                                                   FAILED):
            raise ValueError(f"request id {req.id} is already live")
        self._resolve_sampling(req, seed_offset)
        if req.sampling.stop_strings and self.detokenize is None:
            raise RequestRejected(
                f"request {req.id}: stop_strings need a detokenize= "
                "callable on the engine", request_id=req.id)
        mw = self.serve.max_waiting
        if mw and len(self.sched.waiting) >= mw:
            if self.serve.queue_policy == "reject":
                raise RequestRejected(
                    f"request {req.id}: waiting queue full "
                    f"({mw} requests)", request_id=req.id)
            victim = self.sched.waiting[0]   # shed_oldest
            self._quarantine(victim, RequestRejected(
                f"request {victim.id}: shed from full waiting queue "
                f"({mw} requests) by newer arrival",
                request_id=victim.id))
        req.submit_t = self._clock()
        self.sched.submit(req)          # validates against the pool
        self.requests[req.id] = req
        if self.tracer is not None:
            self.tracer.on_submit(req)
        return req

    def add_request(self, prompt, sampling: Optional[SamplingParams] = None,
                    *, request_id: Optional[int] = None,
                    max_new_tokens: Optional[int] = None,
                    eos_id: Optional[int] = None) -> int:
        """Submit a new generation request; returns its id.  ``prompt``
        is a 1-D sequence of token ids.  Without ``sampling`` the
        default greedy ``SamplingParams()`` applies (the aliases fold
        into it) -- the new API never inherits the deprecated
        engine-global knobs; only Requests submitted through
        ``generate_stream`` without params do.  The request queues FIFO
        and is admitted by a later ``step()``."""
        if sampling is None:
            sampling = SamplingParams()
        rid = request_id
        if rid is None:
            while self._next_id in self.requests:
                self._next_id += 1
            rid = self._next_id
            self._next_id += 1
        req = Request(id=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, sampling=sampling)
        self.submit_request(req)
        return rid

    def get_request(self, request_id: int) -> Optional[Request]:
        return self.requests.get(request_id)

    def abort(self, request_id: int) -> bool:
        """Cancel a request anywhere in its lifecycle: waiting, resuming
        (its host swap stash is dropped), mid-prefill or mid-decode (its
        slot's pages are freed -- shared prefix pages just decref -- and
        its pending COW debts die with it).  Returns False for an
        unknown or already-finished id.  Idempotent."""
        req = self.sched.abort(request_id)
        if req is None:
            return False
        if self.pressure.holds(request_id):
            self.pressure.drop(request_id, reason="abort")
        self.requests.pop(request_id, None)
        self._stop_state.pop(request_id, None)
        if self.spec is not None:
            self.spec.forget(request_id)
        self._c_aborts.inc()
        if self.tracer is not None:
            self.tracer.on_abort(req)
        return True

    # ------------------------------------------------------------------
    # fault isolation
    # ------------------------------------------------------------------
    def _quarantine(self, req: Request, exc: Exception,
                    events: Optional[List[StreamEvent]] = None) -> None:
        """Fail exactly one request in place: its slot's pages are freed
        (shared prefix pages decref'd), its pending COW debts cancelled,
        any host swap stash dropped, and a terminal ``kind="error"``
        event emitted -- co-tenant requests keep serving and their
        outputs are unchanged (greedy sampling is batch-composition
        invariant).  ``events=None`` queues the event for the next
        ``step()`` (submit-time shedding has no step underway)."""
        if isinstance(exc, RequestError):
            detail = exc.detail
        elif isinstance(exc, InjectedFault):
            detail = f"injected: {exc}"
        else:
            detail = f"internal: {exc}"
        self.sched.abort(req.id)        # frees slot/pages/COW wherever it is
        if self.pressure.holds(req.id):
            self.pressure.drop(req.id, reason="fail")
        req.state = FAILED
        req.error = detail
        req.slot = None
        self.requests.pop(req.id, None)
        self._stop_state.pop(req.id, None)
        if self.spec is not None:
            self.spec.forget(req.id)
        if isinstance(exc, RequestTimeout):
            self._c_timeout.inc()
            code = "timed_out"
        elif isinstance(exc, RequestRejected):
            self._c_shed.inc()
            code = "shed"
        else:
            self._c_failed.inc()
            code = "failed"
        self.last_error = f"request {req.id}: {detail}"
        if self.tracer is not None:
            self.tracer.on_fail(req, code)
        if self._step_rec is not None:
            self._step_rec["quarantined"].append(
                {"request_id": req.id, "code": code, "detail": detail})
            self._dump_pending = True
        elif self.flight is not None:
            # submit-time shedding happens outside any step: dump the
            # ring as it stands so the postmortem is not lost
            self.last_flight_dump = self.flight.dump()
        ev = StreamEvent(req.id, -1, len(req.generated), True,
                         kind="error", detail=detail)
        (events if events is not None else self._pending_events).append(ev)

    # ------------------------------------------------------------------
    # jitted paged functions
    # ------------------------------------------------------------------
    def _paged_impl(self) -> str:
        if self.serve.paged_impl == "auto":
            return default_paged_impl()
        return self.serve.paged_impl

    def _paged_fns(self):
        """Jitted paged fns keyed on the resolved impl so a serve-config
        change after first use is honoured: (scan prefill, chunked
        prefill, fused decode step, speculative verify).  The scan
        prefill retraces once per distinct prompt length (that is why it
        is the legacy path); the chunked prefill traces once per launch
        width -- chunk shape, page-table width and position offsets are
        all runtime values.  The verify fn is the chunked prefill
        forward returning the FULL (B, C, V) logits (acceptance needs
        every position, not just the last valid row); it is only ever
        traced when a verify step actually launches, so spec_mode="off"
        never pays for it."""
        impl = self._paged_impl()
        if (impl == "paged" and jax.default_backend() == "tpu"
                and self.serve.page_size % 128):
            raise ValueError(
                f"page_size={self.serve.page_size} must be a multiple of "
                "128 (TPU lane width) for the compiled Pallas paged "
                "kernel; pick a 128-multiple or paged_impl="
                "'paged_reference'")
        key = (impl, self.tp_plan)
        if key not in self._paged_fn_cache:
            model = self.model
            core = self

            def dec(params, tok, pools, table, pos):
                return model.decode_step_paged(params, tok, pools, table,
                                               pos, impl=impl)

            def pre_scan(params, prompt, pools, table_row, pos0):
                # pos0: (1,) int32 runtime offset -- a prefix-cache hit
                # scans only the uncached prompt tail from matched_len
                s = prompt.shape[1]

                def step(c, t):
                    lg, c = model.decode_step_paged(
                        params, prompt[:, t], c, table_row,
                        pos0 + t.astype(jnp.int32), impl=impl)
                    return c, lg

                pools, lgs = jax.lax.scan(step, pools, jnp.arange(s))
                return pools, lgs[-1]

            def pre_chunk(params, chunk, pools, table_row, pos_start,
                          n_valid):
                core.prefill_trace_count += 1  # repro-lint: disable=trace-impurity (trace-count marker)
                logits, pools = model.prefill_chunk_paged(
                    params, chunk, pools, table_row, pos_start, n_valid,
                    impl=impl)
                # the chunk's last *valid* row: only meaningful logits --
                # padding rows attended through the scratch page
                last = jnp.take_along_axis(
                    logits, jnp.maximum(n_valid - 1, 0)[:, None, None],
                    axis=1)[:, 0]
                return pools, last

            def verify(params, chunk, pools, table, pos_start, n_valid):
                core.spec_trace_count += 1  # repro-lint: disable=trace-impurity (trace-count marker)
                logits, pools = model.prefill_chunk_paged(
                    params, chunk, pools, table, pos_start, n_valid,
                    impl=impl)
                return pools, logits

            self._paged_fn_cache[key] = tuple(
                self._tp_wrap(jax.jit(f, donate_argnums=(2,)))
                for f in (pre_scan, pre_chunk, dec, verify))
        return self._paged_fn_cache[key]

    def _tp_wrap(self, fn):
        """Enter the tensor-parallel context around a jitted paged fn so
        the layer code traces onto its shard_map TP bodies (jit traces at
        call time; the contextvar must be live then, not at jit time)."""
        if self.tp_mesh is None:
            return fn
        mesh, plan = self.tp_mesh, self.tp_plan

        def wrapped(*args):
            with tp_context(mesh, plan):
                return fn(*args)

        return wrapped

    # ------------------------------------------------------------------
    # sampling (per-request counter-based RNG)
    # ------------------------------------------------------------------
    def _sample(self, req: Request, logits_row) -> int:
        """Sample the request's next token from its own RNG stream:
        key = fold_in(PRNGKey(seed), token_index).  Greedy requests take
        the argmax (no key consumed), so greedy output is bit-identical
        whatever else shares the batch."""
        sp = req.sampling
        if sp.greedy:
            return int(np.asarray(
                jnp.argmax(logits_row, axis=-1)).ravel()[0])
        key = jax.random.fold_in(jax.random.PRNGKey(sp.seed),
                                 len(req.generated))
        tok = sample_token(jnp.atleast_2d(logits_row), key,
                           temperature=sp.temperature, top_k=sp.top_k)
        return int(np.asarray(tok).ravel()[0])

    def _fire(self, site: str) -> None:
        if self.injector is not None:
            self.injector.fire(site)

    def _guard_logits(self, req: Request, row) -> None:
        """NaN/Inf guard on one request's logits row: under
        ``logit_guard="fail"`` a non-finite row fails only the offending
        request (LogitError -> quarantine); "ignore" samples through it
        (argmax of all-NaN picks index 0 -- garbage, but contained)."""
        if self.serve.logit_guard != "fail":
            return
        if not bool(np.asarray(jnp.all(jnp.isfinite(row)))):
            raise LogitError(
                f"request {req.id}: non-finite logits at token "
                f"{len(req.generated)}", request_id=req.id)

    def _first_token(self, req: Request, slot: int, last_logits,
                     events: List[StreamEvent]) -> None:
        """Sample a freshly-prefilled sequence's first token and flip
        the request into the decoding state.  Sampling faults (injected,
        non-finite logits) quarantine this request only."""
        try:
            self._fire("sample")
            self._guard_logits(req, last_logits)
            tok = self._sample(req, last_logits)
        except (InjectedFault, RequestError) as e:
            self._quarantine(req, e, events)
            return
        req.state = RUNNING
        req.generated.append(tok)
        self.next_tok[slot] = tok
        if self.tracer is not None:
            # first-token opens the running span; on_token counts it so
            # TPOT sees the same token stream the bench does
            self.tracer.on_first_token(req)
            self.tracer.on_token(req)
        self._stream(req, events)

    # ------------------------------------------------------------------
    # event emission (stop-string holdback)
    # ------------------------------------------------------------------
    def _stream(self, req: Request, events: List[StreamEvent]) -> None:
        """Emit the request's not-yet-streamed generated tokens.

        Without stop_strings every new token streams immediately (the
        pre-existing behaviour, bit for bit).  With stop_strings the
        generation is detokenised incrementally; a match ends the
        request with the matched suffix trimmed from the stream, and
        while no match exists the longest text suffix that is a prefix
        of some stop string is *held back* -- a stop string spanning a
        token boundary must never be half-emitted.  Held tokens flush
        when the request finishes for another reason (stop token id,
        max_new_tokens)."""
        gen = req.generated
        sp = req.sampling
        if not sp.stop_strings:
            while req.emitted < len(gen):
                i = req.emitted
                fin = req.done and i == len(gen) - 1
                events.append(StreamEvent(req.id, gen[i], i, fin))
                req.emitted += 1
            return
        st = self._stop_state.setdefault(req.id, {"text": "", "ends": []})
        for i in range(len(st["ends"]), len(gen)):
            # cumulative-prefix decode: piece i is whatever text the
            # i-th token added (robust to multi-token glyphs)
            st["text"] = self.detokenize(gen[:i + 1])
            st["ends"].append(len(st["text"]))
        text, ends = st["text"], st["ends"]
        match = -1
        for s in sp.stop_strings:
            p = text.find(s)
            if p != -1 and (match == -1 or p < match):
                match = p
        if match != -1:
            # emit tokens wholly before the match; the token containing
            # the match start is trimmed with the rest of the suffix
            safe = sum(1 for e in ends if e <= match)
            while req.emitted < safe:
                i = req.emitted
                events.append(StreamEvent(req.id, gen[i], i, False))
                req.emitted += 1
            req.stop_matched = True     # terminal: done is now True
            matched = max((s for s in sp.stop_strings
                           if text.startswith(s, match)), key=len)
            events.append(StreamEvent(req.id, -1, req.emitted, True,
                                      kind="stop", detail=matched))
            self._stop_state.pop(req.id, None)
            return
        if req.done:                    # stop token / length: flush all
            while req.emitted < len(gen):
                i = req.emitted
                events.append(StreamEvent(req.id, gen[i], i,
                                          i == len(gen) - 1))
                req.emitted += 1
            self._stop_state.pop(req.id, None)
            return
        hold = 0
        for s in sp.stop_strings:
            for k in range(min(len(s) - 1, len(text)), 0, -1):
                if text.endswith(s[:k]):
                    hold = max(hold, k)
                    break
        safe_chars = len(text) - hold
        safe = sum(1 for e in ends if e <= safe_chars)
        while req.emitted < safe:
            i = req.emitted
            events.append(StreamEvent(req.id, gen[i], i, False))
            req.emitted += 1

    # ------------------------------------------------------------------
    # page plumbing
    # ------------------------------------------------------------------
    def _ensure_pools(self) -> None:
        if self.pools is None:
            self.pools = self.model.init_paged_cache(self.mgr.num_pages,
                                                     self.mgr.page_size)
            if self.tp_mesh is not None:
                # shard the pools over the TP mesh (kv heads over the
                # head-group axis, within-page rows over the page-row
                # axis) so each device holds 1/tp of the KV budget
                sh = self.model.paged_cache_sharding(
                    self.tp_mesh, self.mgr.num_pages, self.mgr.page_size)
                self.pools = jax.device_put(self.pools, sh)

    def _apply_cow(self) -> None:
        """Replay pending copy-on-write page moves on the device pools:
        the host manager already rewired the page table, the contents
        must follow before the next launch reads or writes the copy."""
        mgr = self.mgr
        if not mgr.cow_pending:
            return
        pairs, mgr.cow_pending = mgr.cow_pending, []
        try:
            self._fire("cow_copy")
        except InjectedFault:
            # debt restored untouched: the caller quarantines the grower
            # (whose abort cancels exactly the debts that die with it)
            # and every other pair stays owed for the next _apply_cow
            mgr.cow_pending = pairs
            raise
        t0 = self._clock() if self._step_rec is not None else 0.0
        self.pools = copy_pages(self.pools, [s for s, _ in pairs],
                                [d for _, d in pairs])
        if self._step_rec is not None:
            ph = self._step_rec["phases"]
            ph["cow_replay"] = ph.get("cow_replay", 0.0) \
                + (self._clock() - t0)

    def _grow(self, slot: int, n: int) -> None:
        """``mgr.append(slot, n)`` with page-pressure relief: on
        OutOfPages, reclaim prefix-cache leaves or evict the newest-
        admitted other sequence (swap or recompute) and retry.
        Terminates because submit-time validation guarantees any single
        request fits the pool alone.  Applies any resulting
        copy-on-write page copies to the device pools."""
        while True:
            try:
                self.mgr.append(slot, n)
                self._apply_cow()
                return
            except OutOfPages:
                self.pressure.relieve(self.pools, protect=slot)

    @staticmethod
    def _prefill_groups(jobs, width: int):
        """Pack this step's prefill jobs into batched launches: first-fit
        into the earliest group that has room and no job for the same
        slot yet (a slot's chunk k+1 must launch after its chunk k; the
        first-fit order preserves that).  Distinct sequences' chunks ride
        one ``prefill_chunk_paged`` call instead of one launch each."""
        groups: list = []
        for job in jobs:
            slot = job[0]
            for g in groups:
                if len(g) < width and all(j[0] != slot for j in g):
                    g.append(job)
                    break
            else:
                groups.append([job])
        return groups

    def _resume_decode(self, req: Request, slot: int) -> None:
        """Flip a resumed sequence whose prefill state is fully restored
        back into decode: its next input token was already sampled before
        the preemption, so nothing is emitted here."""
        req.state = RUNNING
        self.next_tok[slot] = req.generated[-1]

    def _check_invariants(self) -> None:
        self.mgr.check_invariants(
            extern_refs=self.prefix.page_refs() if self.prefix else None)

    # ------------------------------------------------------------------
    # the step
    # ------------------------------------------------------------------
    def step(self) -> List[StreamEvent]:
        """Advance the engine one iteration and return the events it
        produced (possibly none: a step may be all prefill, or idle).
        Event order within a step: terminal events queued since the last
        step (shed requests), deadline expiries, then first tokens of
        sequences whose prefill completed, then one decode token per
        running slot.  Per-request failures (injected faults, non-finite
        logits) quarantine the offending request mid-step -- survivors'
        tokens are bit-identical to a fault-free run; only an
        ``EngineError`` (unrecoverable engine-level breach) propagates
        out -- carrying the flight-recorder dump as ``.flight``."""
        t0 = self._clock()
        if self.flight is not None:
            self._step_rec = {
                "step": self._c_steps.value, "t_start": t0,
                "phases": {}, "events": 0, "quarantined": [],
                "faults_fired": (self.injector.total_fired
                                 if self.injector is not None else 0),
            }
        err: Optional[EngineError] = None
        try:
            events = self._step()
            if self._step_rec is not None:
                self._step_rec["events"] = len(events)
            return events
        except EngineError as e:
            err = e
            raise
        finally:
            dt = self._clock() - t0
            self._h_step.observe(dt)
            self._g_pages.set(self.mgr.used_pages)
            self._g_pages_hw.set(self.mgr.used_pages)
            rec, self._step_rec = self._step_rec, None
            if rec is not None:
                rec["dur_s"] = dt
                rec["pages_used"] = self.mgr.used_pages
                rec["faults_fired"] = \
                    (self.injector.total_fired
                     if self.injector is not None else 0) \
                    - rec["faults_fired"]
                if err is not None:
                    rec["error"] = str(err)
                self.flight.record(rec)
                for phase, pdt in rec["phases"].items():
                    self.metrics.observe(
                        f"engine_phase_{phase}_seconds", pdt)
                if err is not None or self._dump_pending:
                    self._dump_pending = False
                    self.last_flight_dump = self.flight.dump()
                    if err is not None:
                        err.flight = self.last_flight_dump

    def _step(self) -> List[StreamEvent]:
        events: List[StreamEvent] = self._pending_events
        self._pending_events = []
        sched, mgr, serve = self.sched, self.mgr, self.serve
        if not sched.has_work:
            return events
        self._c_steps.inc()
        rec = self._step_rec
        if rec is not None:
            # phase marks: elapsed engine-clock time since the previous
            # mark (cow_replay is accounted inside _apply_cow and may
            # overlap the prefill/decode phases that triggered it)
            clock = self._clock
            last_t = [clock()]

            def mark(phase: str) -> None:
                t = clock()
                ph = rec["phases"]
                ph[phase] = ph.get(phase, 0.0) + (t - last_t[0])
                last_t[0] = t
        else:
            def mark(phase: str) -> None:
                pass
        ps = mgr.page_size
        self._ensure_pools()
        pre_scan, pre_chunk, decode, verify = self._paged_fns()

        # ---- deadline sweep ------------------------------------------
        # before admission, so an already-expired waiting request never
        # takes a slot; expired running requests are quarantined cleanly
        # (pages freed, stash dropped) with a structured timeout event
        now = self._clock()
        expired = [r for r in list(sched.waiting) + list(sched.resuming)
                   if r.deadline_expired(now)]
        expired += [r for _, r in sched.running()
                    if r.deadline_expired(now) and not r.done]
        for req in expired:
            self._quarantine(req, RequestTimeout(
                f"request {req.id}: deadline "
                f"{req.sampling.deadline_ms:g}ms exceeded",
                request_id=req.id), events)
        mark("deadline_sweep")

        for req in sched.retire():
            self.requests.pop(req.id, None)
            if self.spec is not None:
                self.spec.forget(req.id)
        admitted = sched.admit()
        mark("schedule")
        # RESUMING path: swap-preempted requests re-admitted by the
        # scheduler get their stashed KV copied back into the pages
        # admission just materialised (their shared prefix was re-shared
        # from the index); a sequence that was decoding when evicted
        # rejoins the decode batch directly (its next input token was
        # sampled before the preemption).  A stash whose resume was
        # downgraded to recompute is dropped.
        for slot, req in admitted:
            if self.pressure.holds(req.id):
                if req.resume_kind == "swap":
                    try:
                        self.pools = self.pressure.restore(
                            self.pools, slot, req)
                    except SwapRestoreFailed:
                        # H2D failed past its retry budget: downgrade
                        # the resume to recompute -- unwind the slot,
                        # drop the stash, requeue.  Strictly slower,
                        # never a failed request.
                        self.pressure.drop(req.id)
                        self.pressure._bump("swap_fail_downgrades")
                        req.resume_kind = "recompute"
                        req.resume_shared_len = 0
                        sched.preempt(slot)
                        continue
                else:
                    self.pressure.drop(req.id)
            if req.state == RUNNING:
                self.next_tok[slot] = req.generated[-1]
        mark("swap_restore")
        if rec is not None:
            rec["waiting"] = len(sched.waiting)
            rec["resuming"] = len(sched.resuming)
            rec["prefilling"] = len(sched.prefilling())
            rec["decoding"] = len(sched.decoding())
        if not admitted and not sched.running():
            if not sched.waiting and not sched.resuming:
                return events           # everything retired
            if self.injector is not None:
                # an injected admission fault can unwind this step's
                # whole admission -- benign, the queue retries next step
                return events
            # submit-time validation guarantees the head of either queue
            # fits an empty pool (the watermark is waived when no slot is
            # occupied); kept as a tripwire -- reaching it means engine
            # state is inconsistent, not that one request is bad
            req = (sched.resuming or sched.waiting)[0]
            raise EngineError(
                f"pool too small for request {req.id}: needs "
                f"{-(-req.target_len // ps)} pages, pool has "
                f"{mgr.num_pages - 1}")
        if serve.debug_invariants:
            self._check_invariants()

        # ---- prefill phase -------------------------------------------
        chunk = serve.prefill_chunk_tokens
        budget = serve.prefill_budget_tokens
        if serve.prefill_mode == "scan":
            # legacy: the whole uncached (re)prefill tail at once, one
            # token per scan step, retraced per length (equivalence
            # oracle); a prefix-cache hit starts the scan at matched_len
            # over the shared pages
            for slot, req in admitted:
                if sched.slots[slot] is not req \
                        or req.state != PREFILLING:
                    continue            # preempted again, or swap-resumed
                try:
                    # launch-site faults fire BEFORE any page mutation:
                    # the untouched prefill simply retries next step
                    self._fire("prefill_launch")
                except InjectedFault:
                    continue
                start = req.prefilled
                toks = req.prefill_tokens[start:]
                try:
                    self._grow(slot, len(toks))
                except InjectedFault as e:
                    self._quarantine(req, e, events)
                    continue
                # repro-lint: disable=retrace-hazard (the scan
                # oracle deliberately traces per prompt length; the
                # production path is the chunked paged prefill)
                self.pools, last_logits = pre_scan(
                    self.params, jnp.asarray(toks[None]), self.pools,
                    jnp.asarray(mgr.device_row(slot)),
                    jnp.full((1,), start, jnp.int32))
                req.prefilled = start + len(toks)
                if req.generated:
                    self._resume_decode(req, slot)
                else:
                    self._first_token(req, slot, last_logits, events)
        else:
            # chunked: fixed-size chunks through the full forward, jobs
            # for distinct sequences batched into one launch, padded to
            # the next power-of-two row count (a lone prefilling prompt
            # stays a 1-row launch; traces stay bounded by
            # log2(max_batch)+1 widths, never by prompt length)
            width = serve.max_batch
            for group in self._prefill_groups(
                    sched.prefill_schedule(budget, chunk), width):
                try:
                    # fires BEFORE the group's page growth; skipping the
                    # REST of this step's prefill keeps chunk order (a
                    # slot's chunk k+1 must never launch before chunk k)
                    self._fire("prefill_launch")
                except InjectedFault:
                    break
                live = []
                for slot, req, start, n in group:
                    if sched.slots[slot] is not req \
                            or req.state != PREFILLING:
                        continue        # victim of an earlier _grow
                    try:
                        self._grow(slot, n)
                    except InjectedFault as e:
                        self._quarantine(req, e, events)
                        continue
                    live.append((slot, req, start, n))
                # _grow may have evicted an earlier group member
                live = [(s, r, st, n) for s, r, st, n in live
                        if sched.slots[s] is r]
                if not live:
                    continue
                bw = 1
                while bw < len(live):
                    bw *= 2
                bw = min(bw, width)
                buf = np.zeros((bw, chunk), np.int32)
                table = np.full((bw, mgr.max_pages_per_seq),
                                mgr.SCRATCH, np.int32)
                pos0 = np.zeros((bw,), np.int32)
                nval = np.zeros((bw,), np.int32)
                for i, (slot, req, start, n) in enumerate(live):
                    buf[i, :n] = req.prefill_tokens[start:start + n]
                    table[i] = mgr.table[slot]
                    pos0[i] = start
                    nval[i] = n
                self.prefill_launches += 1
                self.pools, last_logits = pre_chunk(
                    self.params, jnp.asarray(buf), self.pools,
                    jnp.asarray(table), jnp.asarray(pos0),
                    jnp.asarray(nval))
                for i, (slot, req, start, n) in enumerate(live):
                    req.prefilled = start + n
                    if not req.prefill_done:
                        continue
                    if req.generated:   # recompute-resume finished
                        self._resume_decode(req, slot)
                    else:
                        self._first_token(req, slot,
                                          last_logits[i:i + 1], events)

        mark("prefill")

        # ---- decode phase --------------------------------------------
        cand = [(s, r) for s, r in sched.decoding() if not r.done]
        try:
            # fires BEFORE the decode _grows: the whole decode phase is
            # skipped untouched this step and retries on the next one
            self._fire("decode_launch")
        except InjectedFault:
            cand = []
        if self.spec is not None and cand:
            # speculative path: draft + multi-token verify replaces the
            # one-token decode launch.  ``spec is None`` keeps the plain
            # path below byte-for-byte (greedy output is bit-identical
            # either way; only the launch count differs).
            self._spec_decode(cand, events, mark, verify)
            self._c_events.inc(len(events))
            return events
        # materialise the page (maybe a fresh one) every running
        # sequence's next token will be written to -- evicting other
        # sequences under pressure -- THEN snapshot the table for the
        # device step.
        for slot, req in cand:
            if sched.slots[slot] is not req:
                continue                # evicted by an earlier _grow
            try:
                self._grow(slot, 1)
            except InjectedFault as e:
                self._quarantine(req, e, events)
        running = [(s, r) for s, r in cand if sched.slots[s] is r]
        if serve.debug_invariants:
            self._check_invariants()
        if not running:
            mark("decode")
            self._c_events.inc(len(events))
            return events
        pos_np = np.zeros((serve.max_batch,), np.int32)
        for slot, _ in running:
            pos_np[slot] = mgr.seq_len(slot) - 1
        table = mgr.device_table()
        for slot, _ in sched.prefilling():
            # mid-prefill slots sit out the decode step: scratch-page
            # table row + pos 0, like idle slots (their real pages must
            # not see the decode step's writes)
            table[slot, :] = mgr.SCRATCH
        logits, self.pools = decode(
            self.params, jnp.asarray(self.next_tok), self.pools,
            jnp.asarray(table), jnp.asarray(pos_np))
        mark("decode")
        rowok = None
        if serve.logit_guard == "fail":
            # one device-side reduction + a max_batch-bool transfer: the
            # guard never pulls the full logits to host
            rowok = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
        if all(r.sampling.greedy for _, r in running):
            # one batched argmax: the common all-greedy step costs one
            # device op, and matches the pre-core engine bit for bit
            toks = np.asarray(jnp.argmax(logits, axis=-1)
                              .astype(jnp.int32))
            picked = {slot: int(toks[slot]) for slot, _ in running}
        else:
            # mixed sampling: one host sync, then per-row eager sampling
            # -- O(batch) small dispatches per step, acceptable at the
            # decode batch widths served here; a batched vmapped sampler
            # keyed on (temperature, top_k) groups is the upgrade path
            logits_np = np.asarray(logits)
            picked = {slot: self._sample(req, logits_np[slot])
                      for slot, req in running}
        mark("sample")
        for slot, req in running:
            try:
                self._fire("sample")
                if rowok is not None and not rowok[slot]:
                    raise LogitError(
                        f"request {req.id}: non-finite logits at token "
                        f"{len(req.generated)}", request_id=req.id)
            except (InjectedFault, RequestError) as e:
                self._quarantine(req, e, events)
                continue
            tok = picked[slot]
            req.generated.append(tok)
            self.next_tok[slot] = tok
            if self.tracer is not None:
                self.tracer.on_token(req)
            self._stream(req, events)
        mark("detok")
        self._c_events.inc(len(events))
        return events

    # ------------------------------------------------------------------
    # speculative decode phase (serving/spec.py)
    # ------------------------------------------------------------------
    def _spec_decode(self, cand, events: List[StreamEvent], mark,
                     verify) -> None:
        """One speculative step for every running slot: draft up to K
        continuation tokens from the request's own text, append them to
        the paged KV (COW-safe multi-token ``append``) and score all
        K+1 positions in ONE chunked paged-prefill launch, then keep
        the accepted prefix plus one correction/bonus token and
        ``truncate`` the rejected rows' KV exactly -- restoring the
        RUNNING invariant ``seq_len == len(prompt)+len(generated)-1``
        so prefix sharing, preemption/swap and quarantine compose
        unchanged.  The ``spec_verify`` fault site fires before any
        drafting; an injected fault degrades the step to K=0 (a
        one-token verify -- same tokens, strictly no speculation)."""
        sched, mgr, serve = self.sched, self.mgr, self.serve
        width = serve.spec_tokens + 1
        try:
            self._fire("spec_verify")
            drafts = {}
            for slot, req in cand:
                # cap K so a fully-accepted run plus its bonus token
                # never overshoots max_new_tokens (also bounds page
                # growth to what submit-time validation admitted)
                cap = min(serve.spec_tokens,
                          req.max_new_tokens - len(req.generated) - 1)
                drafts[slot] = (self.spec.propose(req)[:cap]
                                if cap > 0 else [])
        except InjectedFault:
            drafts = {slot: [] for slot, _ in cand}
        for slot, req in cand:
            if sched.slots[slot] is not req:
                continue                # evicted by an earlier _grow
            try:
                self._grow(slot, 1 + len(drafts[slot]))
            except InjectedFault as e:
                self._quarantine(req, e, events)
        running = [(s, r) for s, r in cand if sched.slots[s] is r]
        if serve.debug_invariants:
            self._check_invariants()
        if not running:
            mark("verify")
            return
        # slot-indexed batch like the decode step, but every row NOT in
        # the verify batch gets a scratch table row: with n_valid=0 all
        # its K/V writes land in the scratch page, so prefilling, done
        # and idle slots never see this launch
        buf = np.zeros((serve.max_batch, width), np.int32)
        table = np.full((serve.max_batch, mgr.max_pages_per_seq),
                        mgr.SCRATCH, np.int32)
        pos0 = np.zeros((serve.max_batch,), np.int32)
        nval = np.zeros((serve.max_batch,), np.int32)
        for slot, req in running:
            d = drafts[slot]
            buf[slot, 0] = self.next_tok[slot]
            if d:
                buf[slot, 1:1 + len(d)] = d
            table[slot] = mgr.table[slot]
            pos0[slot] = mgr.seq_len(slot) - (1 + len(d))
            nval[slot] = 1 + len(d)
        self.spec_launches += 1
        self.pools, logits = verify(
            self.params, jnp.asarray(buf), self.pools,
            jnp.asarray(table), jnp.asarray(pos0), jnp.asarray(nval))
        mark("verify")
        rowok = None
        if serve.logit_guard == "fail":
            # (B, width) bools: acceptance guards each row only when its
            # logits are consumed, so K=0 matches the plain path exactly
            rowok = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
        argm = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        logits_np = (np.asarray(logits)
                     if any(not r.sampling.greedy for _, r in running)
                     else None)
        survivors = []
        for slot, req in running:
            d = drafts[slot]
            sp = req.sampling
            old_len = int(pos0[slot])
            ok_row = rowok[slot] if rowok is not None else None
            try:
                self._fire("sample")
                if sp.greedy:
                    toks, acc = verify_greedy(
                        d, argm[slot], stop_ids=req.stop_token_ids,
                        budget=req.max_new_tokens - len(req.generated),
                        row_ok=ok_row, request_id=req.id,
                        n0=len(req.generated))
                else:
                    toks, acc = verify_residual(
                        d, logits_np[slot], seed=sp.seed,
                        n0=len(req.generated),
                        temperature=sp.temperature, top_k=sp.top_k,
                        stop_ids=req.stop_token_ids,
                        budget=req.max_new_tokens - len(req.generated),
                        row_ok=ok_row, request_id=req.id)
            except (InjectedFault, RequestError) as e:
                self._quarantine(req, e, events)
                continue
            if d:
                self.spec.observe(req.id, len(d), acc)
                self._c_spec_drafted.inc(len(d))
                self._c_spec_accepted.inc(acc)
                self._h_spec_accept.observe(acc / len(d))
                self._h_spec_run.observe(float(acc))
            for tok in toks:
                req.generated.append(int(tok))
                if self.tracer is not None:
                    self.tracer.on_token(req)
            self.next_tok[slot] = req.generated[-1]
            # exact rollback: drop the rejected drafts' KV rows and the
            # (never-written) row grown for the newest sampled token
            mgr.truncate(slot, old_len + len(toks))
            survivors.append((slot, req))
        if serve.debug_invariants:
            self._check_invariants()
        mark("sample")
        for slot, req in survivors:
            self._stream(req, events)
        mark("detok")
