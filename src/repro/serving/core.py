"""EngineCore: the persistent, iteration-level serving engine.

vLLM's ``LLMEngine.add_request``/``step`` and FlashInfer's decoupled
plan/run interface expose the same shape: a *core* that owns all serving
state -- page manager, scheduler, pressure manager, radix prefix index,
device page pools, jitted paged functions -- and advances the whole
system exactly one iteration per ``step()`` call.  Frontends, arrival
processes and multi-tenant policies then compose on top, and features
that need a step boundary to hook into (overlapped swap, multi-host
decode) have one.

    core = EngineCore(model=model, params=params, cfg=cfg, serve=serve)
    rid = core.add_request(prompt, SamplingParams(max_new_tokens=32))
    while core.has_work:
        for ev in core.step():          # list[StreamEvent], may be empty
            ...
    core.abort(rid)                     # any time: frees pages, no leaks

Everything persists across requests unconditionally -- the prefix-cache-
only ``_shared_state`` special case of the previous ``ServeEngine`` is
gone: abandoning a stream is now a plain ``abort()`` (free the slot's
pages, cancel its copy-on-write debts, drop any swap stash; shared
prefix pages just lose one reference).

Sampling is per request (``SamplingParams``) with a counter-based RNG:
the key for a request's n-th sampled token is
``fold_in(PRNGKey(params.seed), n)``, so sampled tokens are invariant to
batch composition, co-tenants, preemption and admission order.  The
engine-global ``ServeConfig.temperature/top_k`` knobs survive only as
deprecated defaults for requests submitted without params.

``ServeEngine.generate_stream`` is a thin compatibility wrapper over
this class (submit, drain ``step()``, abort leftovers on close) -- its
greedy output is bit-identical to the pre-core engine.
"""
from __future__ import annotations

import warnings
from collections import deque
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core.fastattention import default_paged_impl
from repro.serving.paged_cache import OutOfPages, PagedKVCache
from repro.serving.prefix_cache import RadixPrefixIndex
from repro.serving.pressure import PressureManager, copy_pages
from repro.serving.scheduler import (ABORTED, FINISHED, PREFILLING, RUNNING,
                                     ContinuousBatchScheduler, Request,
                                     SamplingParams)
from repro.sharding.tp import plan_tp, tp_context


def sample_token(logits, key, *, temperature: float = 1.0, top_k: int = 0):
    if temperature == 0.0 or top_k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 1:
        # lax.top_k rejects k > vocab; clamping makes oversized k mean
        # "no truncation" instead of a crash
        k = min(top_k, lf.shape[-1])
        vals, _ = jax.lax.top_k(lf, k)
        thresh = vals[..., -1:]
        lf = jnp.where(lf < thresh, -1e30, lf)
    return jax.random.categorical(key, lf).astype(jnp.int32)


class StreamEvent(NamedTuple):
    """One generated token, emitted the step it exists."""
    request_id: int
    token: int
    index: int            # position within the request's generation
    finished: bool        # True on the request's last token


class _CountingDeque(deque):
    """Bounded deque that counts evictions instead of losing them
    silently: a full ``append`` still drops the oldest entry (the bound
    is the point), but ``dropped`` records how many orphaned events were
    lost so ``stats()`` can surface the loss."""

    def __init__(self, maxlen: int):
        super().__init__(maxlen=maxlen)
        self.dropped = 0

    def append(self, item) -> None:
        if self.maxlen is not None and len(self) == self.maxlen:
            self.dropped += 1
        super().append(item)


class EngineCore:
    """Persistent iteration-level engine over the paged KV cache.

    One ``step()`` = retire finished sequences, admit waiting/resuming
    requests, spend the prefill token budget on chunked prompt prefill,
    run one fused decode step for every RUNNING slot, and return the
    tokens produced.  All state lives on the core and survives between
    calls -- including the device page pools, so prefix-cache hits keep
    their KV across requests.
    """

    def __init__(self, model, params, cfg: ModelConfig,
                 serve: Optional[ServeConfig] = None, *,
                 fn_cache: Optional[dict] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.serve = serve or ServeConfig()
        # tensor parallelism (sharding/tp.py): factor serve.tp into
        # kv-head groups x page-row sub-shards and bind a 2-D mesh; the
        # paged forward fns trace under tp_context, flipping the
        # attention/MLP layers onto their shard_map TP bodies
        self.tp_plan = None
        self.tp_mesh = None
        if self.serve.tp > 1:
            from repro.launch.mesh import make_mesh
            plan = plan_tp(cfg, self.serve.tp, self.serve.page_size,
                           collectives=self.serve.tp_collectives,
                           ar_chunks=self.serve.tp_ar_chunks,
                           first_chunk_frac=self.serve.tp_first_chunk_frac)
            if jax.device_count() < plan.tp:
                raise ValueError(
                    f"tp={plan.tp} needs {plan.tp} devices, "
                    f"found {jax.device_count()}")
            self.tp_plan = plan
            self.tp_mesh = make_mesh(plan.mesh_shape, plan.axes)
        # jitted paged prefill/decode triples keyed by (resolved impl,
        # tp plan); shared with the ServeEngine wrapper so clearing one
        # clears both
        self._paged_fn_cache = fn_cache if fn_cache is not None else {}
        # how many times the chunked-prefill function was *traced* (not
        # called): the trace-count test asserts it stays bounded by
        # launch widths no matter how many prompt lengths stream through
        self.prefill_trace_count = 0
        # prefill chunk *launches* (calls, not traces): prefix-cache hits
        # skip the matched prefix's launches entirely, asserted in tests
        self.prefill_launches = 0
        self._warned_legacy_sampling = False
        self._next_id = 0
        self.reset()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Drop every request, page, stash and cached prefix and rebuild
        the serving state from ``self.serve``.  Jit caches and trace
        counters survive (they are keyed by shapes, not state)."""
        serve = self.serve
        self.mgr = PagedKVCache(serve.pool_pages(), serve.page_size,
                                serve.max_batch, serve.max_pages_per_seq)
        self.prefix = (RadixPrefixIndex(self.mgr, serve.page_size,
                                        serve.prefix_cache_pages)
                       if serve.prefix_cache else None)
        self.sched = ContinuousBatchScheduler(
            self.mgr, serve.max_batch, admission=serve.admission,
            watermark_pages=serve.watermark, prefix_cache=self.prefix)
        self.pressure = PressureManager(self.cfg, serve, self.mgr,
                                        self.sched,
                                        prefix_cache=self.prefix)
        self.pools = None              # device pools, materialised lazily
        self.next_tok = np.zeros((serve.max_batch,), np.int32)
        self.requests: Dict[int, Request] = {}     # live (unfinished) only
        # events a generate_stream drain stepped out for requests no
        # drain owns (direct add_request users): step() hands each event
        # to exactly one caller, so mixed-mode users recover them here
        # (drops past the bound are counted, see stats()["orphans_dropped"])
        self.orphan_events: _CountingDeque = _CountingDeque(maxlen=4096)
        self.steps = 0
        self.events_emitted = 0
        self.aborts = 0

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    def stats(self) -> dict:
        """Point-in-time engine statistics (live objects, not a log)."""
        mgr, sched = self.mgr, self.sched
        out = {
            "steps": self.steps,
            "events_emitted": self.events_emitted,
            "aborts": self.aborts,
            "waiting": len(sched.waiting),
            "resuming": len(sched.resuming),
            "active_slots": sum(1 for r in sched.slots if r is not None),
            "finished": sched.finished_count,
            "pages_used": mgr.used_pages,
            "pages_free": mgr.free_pages,
            "pages_peak": mgr.peak_used_pages,
            "peak_utilization": mgr.peak_utilization,
            "prefill_launches": self.prefill_launches,
            "prefill_trace_count": self.prefill_trace_count,
            "orphan_events_pending": len(self.orphan_events),
            "orphans_dropped": self.orphan_events.dropped,
            "pressure": dict(self.pressure.stats),
            "host_pool_pages": self.pressure.host_pool.used_pages,
        }
        if self.prefix is not None:
            out["prefix"] = dict(self.prefix.stats)
            out["prefix_cached_pages"] = self.prefix.cached_pages
        if self.tp_plan is not None:
            out["tp"] = {"tp": self.tp_plan.tp, "g": self.tp_plan.g,
                         "s": self.tp_plan.s,
                         "collectives": self.tp_plan.collectives}
        return out

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def _resolve_sampling(self, req: Request, seed_offset: int = 0) -> None:
        """Give a params-less request its SamplingParams from the
        deprecated engine-global knobs (warning once per core when they
        were actually changed from their defaults).  The legacy seed
        folds in the request id so co-scheduled legacy requests do not
        sample identical streams."""
        if req.sampling is not None:
            return
        serve = self.serve
        if serve.sampling_overridden and not self._warned_legacy_sampling:
            self._warned_legacy_sampling = True
            warnings.warn(
                "engine-global ServeConfig.temperature/top_k are "
                "deprecated: pass SamplingParams per request "
                "(Request(sampling=...) or EngineCore.add_request)",
                DeprecationWarning, stacklevel=4)
        req.sampling = SamplingParams(
            temperature=serve.temperature, top_k=serve.top_k,
            seed=serve.seed + seed_offset + int(req.id),
            max_new_tokens=req.max_new_tokens,
            stop_token_ids=(req.eos_id,) if req.eos_id is not None else ())

    def submit_request(self, req: Request, *, seed_offset: int = 0
                       ) -> Request:
        """Validate and enqueue a pre-built ``Request`` (the
        generate_stream compatibility path).  Raises ValueError when the
        request can never fit the pool or its id collides with a live
        request."""
        live = self.requests.get(req.id)
        if live is not None and live.state not in (FINISHED, ABORTED):
            raise ValueError(f"request id {req.id} is already live")
        self._resolve_sampling(req, seed_offset)
        self.sched.submit(req)          # validates against the pool
        self.requests[req.id] = req
        return req

    def add_request(self, prompt, sampling: Optional[SamplingParams] = None,
                    *, request_id: Optional[int] = None,
                    max_new_tokens: Optional[int] = None,
                    eos_id: Optional[int] = None) -> int:
        """Submit a new generation request; returns its id.  ``prompt``
        is a 1-D sequence of token ids.  Without ``sampling`` the
        default greedy ``SamplingParams()`` applies (the aliases fold
        into it) -- the new API never inherits the deprecated
        engine-global knobs; only Requests submitted through
        ``generate_stream`` without params do.  The request queues FIFO
        and is admitted by a later ``step()``."""
        if sampling is None:
            sampling = SamplingParams()
        rid = request_id
        if rid is None:
            while self._next_id in self.requests:
                self._next_id += 1
            rid = self._next_id
            self._next_id += 1
        req = Request(id=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, sampling=sampling)
        self.submit_request(req)
        return rid

    def get_request(self, request_id: int) -> Optional[Request]:
        return self.requests.get(request_id)

    def abort(self, request_id: int) -> bool:
        """Cancel a request anywhere in its lifecycle: waiting, resuming
        (its host swap stash is dropped), mid-prefill or mid-decode (its
        slot's pages are freed -- shared prefix pages just decref -- and
        its pending COW debts die with it).  Returns False for an
        unknown or already-finished id.  Idempotent."""
        req = self.sched.abort(request_id)
        if req is None:
            return False
        if self.pressure.holds(request_id):
            self.pressure.drop(request_id, reason="abort")
        self.requests.pop(request_id, None)
        self.aborts += 1
        return True

    # ------------------------------------------------------------------
    # jitted paged functions
    # ------------------------------------------------------------------
    def _paged_impl(self) -> str:
        if self.serve.paged_impl == "auto":
            return default_paged_impl()
        return self.serve.paged_impl

    def _paged_fns(self):
        """Jitted paged fns keyed on the resolved impl so a serve-config
        change after first use is honoured: (scan prefill, chunked
        prefill, fused decode step).  The scan prefill retraces once per
        distinct prompt length (that is why it is the legacy path); the
        chunked prefill traces once per launch width -- chunk shape,
        page-table width and position offsets are all runtime values."""
        impl = self._paged_impl()
        if (impl == "paged" and jax.default_backend() == "tpu"
                and self.serve.page_size % 128):
            raise ValueError(
                f"page_size={self.serve.page_size} must be a multiple of "
                "128 (TPU lane width) for the compiled Pallas paged "
                "kernel; pick a 128-multiple or paged_impl="
                "'paged_reference'")
        key = (impl, self.tp_plan)
        if key not in self._paged_fn_cache:
            model = self.model
            core = self

            def dec(params, tok, pools, table, pos):
                return model.decode_step_paged(params, tok, pools, table,
                                               pos, impl=impl)

            def pre_scan(params, prompt, pools, table_row, pos0):
                # pos0: (1,) int32 runtime offset -- a prefix-cache hit
                # scans only the uncached prompt tail from matched_len
                s = prompt.shape[1]

                def step(c, t):
                    lg, c = model.decode_step_paged(
                        params, prompt[:, t], c, table_row,
                        pos0 + t.astype(jnp.int32), impl=impl)
                    return c, lg

                pools, lgs = jax.lax.scan(step, pools, jnp.arange(s))
                return pools, lgs[-1]

            def pre_chunk(params, chunk, pools, table_row, pos_start,
                          n_valid):
                core.prefill_trace_count += 1      # host-side, trace-time
                logits, pools = model.prefill_chunk_paged(
                    params, chunk, pools, table_row, pos_start, n_valid,
                    impl=impl)
                # the chunk's last *valid* row: only meaningful logits --
                # padding rows attended through the scratch page
                last = jnp.take_along_axis(
                    logits, jnp.maximum(n_valid - 1, 0)[:, None, None],
                    axis=1)[:, 0]
                return pools, last

            self._paged_fn_cache[key] = tuple(
                self._tp_wrap(jax.jit(f, donate_argnums=(2,)))
                for f in (pre_scan, pre_chunk, dec))
        return self._paged_fn_cache[key]

    def _tp_wrap(self, fn):
        """Enter the tensor-parallel context around a jitted paged fn so
        the layer code traces onto its shard_map TP bodies (jit traces at
        call time; the contextvar must be live then, not at jit time)."""
        if self.tp_mesh is None:
            return fn
        mesh, plan = self.tp_mesh, self.tp_plan

        def wrapped(*args):
            with tp_context(mesh, plan):
                return fn(*args)

        return wrapped

    # ------------------------------------------------------------------
    # sampling (per-request counter-based RNG)
    # ------------------------------------------------------------------
    def _sample(self, req: Request, logits_row) -> int:
        """Sample the request's next token from its own RNG stream:
        key = fold_in(PRNGKey(seed), token_index).  Greedy requests take
        the argmax (no key consumed), so greedy output is bit-identical
        whatever else shares the batch."""
        sp = req.sampling
        if sp.greedy:
            return int(np.asarray(
                jnp.argmax(logits_row, axis=-1)).ravel()[0])
        key = jax.random.fold_in(jax.random.PRNGKey(sp.seed),
                                 len(req.generated))
        tok = sample_token(jnp.atleast_2d(logits_row), key,
                           temperature=sp.temperature, top_k=sp.top_k)
        return int(np.asarray(tok).ravel()[0])

    def _first_token(self, req: Request, slot: int,
                     last_logits) -> StreamEvent:
        """Sample a freshly-prefilled sequence's first token and flip the
        request into the decoding state."""
        req.state = RUNNING
        tok = self._sample(req, last_logits)
        req.generated.append(tok)
        self.next_tok[slot] = tok
        return StreamEvent(req.id, tok, 0, req.done)

    # ------------------------------------------------------------------
    # page plumbing
    # ------------------------------------------------------------------
    def _ensure_pools(self) -> None:
        if self.pools is None:
            self.pools = self.model.init_paged_cache(self.mgr.num_pages,
                                                     self.mgr.page_size)
            if self.tp_mesh is not None:
                # shard the pools over the TP mesh (kv heads over the
                # head-group axis, within-page rows over the page-row
                # axis) so each device holds 1/tp of the KV budget
                sh = self.model.paged_cache_sharding(
                    self.tp_mesh, self.mgr.num_pages, self.mgr.page_size)
                self.pools = jax.device_put(self.pools, sh)

    def _apply_cow(self) -> None:
        """Replay pending copy-on-write page moves on the device pools:
        the host manager already rewired the page table, the contents
        must follow before the next launch reads or writes the copy."""
        mgr = self.mgr
        if not mgr.cow_pending:
            return
        pairs, mgr.cow_pending = mgr.cow_pending, []
        self.pools = copy_pages(self.pools, [s for s, _ in pairs],
                                [d for _, d in pairs])

    def _grow(self, slot: int, n: int) -> None:
        """``mgr.append(slot, n)`` with page-pressure relief: on
        OutOfPages, reclaim prefix-cache leaves or evict the newest-
        admitted other sequence (swap or recompute) and retry.
        Terminates because submit-time validation guarantees any single
        request fits the pool alone.  Applies any resulting
        copy-on-write page copies to the device pools."""
        while True:
            try:
                self.mgr.append(slot, n)
                self._apply_cow()
                return
            except OutOfPages:
                self.pressure.relieve(self.pools, protect=slot)

    @staticmethod
    def _prefill_groups(jobs, width: int):
        """Pack this step's prefill jobs into batched launches: first-fit
        into the earliest group that has room and no job for the same
        slot yet (a slot's chunk k+1 must launch after its chunk k; the
        first-fit order preserves that).  Distinct sequences' chunks ride
        one ``prefill_chunk_paged`` call instead of one launch each."""
        groups: list = []
        for job in jobs:
            slot = job[0]
            for g in groups:
                if len(g) < width and all(j[0] != slot for j in g):
                    g.append(job)
                    break
            else:
                groups.append([job])
        return groups

    def _resume_decode(self, req: Request, slot: int) -> None:
        """Flip a resumed sequence whose prefill state is fully restored
        back into decode: its next input token was already sampled before
        the preemption, so nothing is emitted here."""
        req.state = RUNNING
        self.next_tok[slot] = req.generated[-1]

    def _check_invariants(self) -> None:
        self.mgr.check_invariants(
            extern_refs=self.prefix.page_refs() if self.prefix else None)

    # ------------------------------------------------------------------
    # the step
    # ------------------------------------------------------------------
    def step(self) -> List[StreamEvent]:
        """Advance the engine one iteration and return the tokens it
        produced (possibly none: a step may be all prefill, or idle).
        Event order within a step: first tokens of sequences whose
        prefill completed, then one decode token per running slot."""
        events: List[StreamEvent] = []
        sched, mgr, serve = self.sched, self.mgr, self.serve
        if not sched.has_work:
            return events
        self.steps += 1
        ps = mgr.page_size
        self._ensure_pools()
        pre_scan, pre_chunk, decode = self._paged_fns()

        for req in sched.retire():
            self.requests.pop(req.id, None)
        admitted = sched.admit()
        # RESUMING path: swap-preempted requests re-admitted by the
        # scheduler get their stashed KV copied back into the pages
        # admission just materialised (their shared prefix was re-shared
        # from the index); a sequence that was decoding when evicted
        # rejoins the decode batch directly (its next input token was
        # sampled before the preemption).  A stash whose resume was
        # downgraded to recompute is dropped.
        for slot, req in admitted:
            if self.pressure.holds(req.id):
                if req.resume_kind == "swap":
                    self.pools = self.pressure.restore(self.pools, slot,
                                                       req)
                else:
                    self.pressure.drop(req.id)
            if req.state == RUNNING:
                self.next_tok[slot] = req.generated[-1]
        if not admitted and not sched.running():
            if not sched.waiting and not sched.resuming:
                return events           # everything retired
            # submit-time validation guarantees the head of either queue
            # fits an empty pool (the watermark is waived when no slot is
            # occupied); kept as a cheap tripwire
            req = (sched.resuming or sched.waiting)[0]
            raise RuntimeError(
                f"pool too small for request {req.id}: needs "
                f"{-(-req.target_len // ps)} pages, pool has "
                f"{mgr.num_pages - 1}")
        if serve.debug_invariants:
            self._check_invariants()

        # ---- prefill phase -------------------------------------------
        chunk = serve.prefill_chunk_tokens
        budget = serve.prefill_budget_tokens
        if serve.prefill_mode == "scan":
            # legacy: the whole uncached (re)prefill tail at once, one
            # token per scan step, retraced per length (equivalence
            # oracle); a prefix-cache hit starts the scan at matched_len
            # over the shared pages
            for slot, req in admitted:
                if sched.slots[slot] is not req \
                        or req.state != PREFILLING:
                    continue            # preempted again, or swap-resumed
                start = req.prefilled
                toks = req.prefill_tokens[start:]
                self._grow(slot, len(toks))
                self.pools, last_logits = pre_scan(
                    self.params, jnp.asarray(toks[None]), self.pools,
                    jnp.asarray(mgr.device_row(slot)),
                    jnp.full((1,), start, jnp.int32))
                req.prefilled = start + len(toks)
                if req.generated:
                    self._resume_decode(req, slot)
                else:
                    events.append(self._first_token(req, slot,
                                                    last_logits))
        else:
            # chunked: fixed-size chunks through the full forward, jobs
            # for distinct sequences batched into one launch, padded to
            # the next power-of-two row count (a lone prefilling prompt
            # stays a 1-row launch; traces stay bounded by
            # log2(max_batch)+1 widths, never by prompt length)
            width = serve.max_batch
            for group in self._prefill_groups(
                    sched.prefill_schedule(budget, chunk), width):
                live = []
                for slot, req, start, n in group:
                    if sched.slots[slot] is not req \
                            or req.state != PREFILLING:
                        continue        # victim of an earlier _grow
                    self._grow(slot, n)
                    live.append((slot, req, start, n))
                # _grow may have evicted an earlier group member
                live = [(s, r, st, n) for s, r, st, n in live
                        if sched.slots[s] is r]
                if not live:
                    continue
                bw = 1
                while bw < len(live):
                    bw *= 2
                bw = min(bw, width)
                buf = np.zeros((bw, chunk), np.int32)
                table = np.full((bw, mgr.max_pages_per_seq),
                                mgr.SCRATCH, np.int32)
                pos0 = np.zeros((bw,), np.int32)
                nval = np.zeros((bw,), np.int32)
                for i, (slot, req, start, n) in enumerate(live):
                    buf[i, :n] = req.prefill_tokens[start:start + n]
                    table[i] = mgr.table[slot]
                    pos0[i] = start
                    nval[i] = n
                self.prefill_launches += 1
                self.pools, last_logits = pre_chunk(
                    self.params, jnp.asarray(buf), self.pools,
                    jnp.asarray(table), jnp.asarray(pos0),
                    jnp.asarray(nval))
                for i, (slot, req, start, n) in enumerate(live):
                    req.prefilled = start + n
                    if not req.prefill_done:
                        continue
                    if req.generated:   # recompute-resume finished
                        self._resume_decode(req, slot)
                    else:
                        events.append(self._first_token(
                            req, slot, last_logits[i:i + 1]))

        # ---- decode phase --------------------------------------------
        cand = [(s, r) for s, r in sched.decoding() if not r.done]
        # materialise the page (maybe a fresh one) every running
        # sequence's next token will be written to -- evicting other
        # sequences under pressure -- THEN snapshot the table for the
        # device step.
        for slot, req in cand:
            if sched.slots[slot] is not req:
                continue                # evicted by an earlier _grow
            self._grow(slot, 1)
        running = [(s, r) for s, r in cand if sched.slots[s] is r]
        if serve.debug_invariants:
            self._check_invariants()
        if not running:
            self.events_emitted += len(events)
            return events
        pos_np = np.zeros((serve.max_batch,), np.int32)
        for slot, _ in running:
            pos_np[slot] = mgr.seq_len(slot) - 1
        table = mgr.device_table()
        for slot, _ in sched.prefilling():
            # mid-prefill slots sit out the decode step: scratch-page
            # table row + pos 0, like idle slots (their real pages must
            # not see the decode step's writes)
            table[slot, :] = mgr.SCRATCH
        logits, self.pools = decode(
            self.params, jnp.asarray(self.next_tok), self.pools,
            jnp.asarray(table), jnp.asarray(pos_np))
        if all(r.sampling.greedy for _, r in running):
            # one batched argmax: the common all-greedy step costs one
            # device op, and matches the pre-core engine bit for bit
            toks = np.asarray(jnp.argmax(logits, axis=-1)
                              .astype(jnp.int32))
            picked = {slot: int(toks[slot]) for slot, _ in running}
        else:
            # mixed sampling: one host sync, then per-row eager sampling
            # -- O(batch) small dispatches per step, acceptable at the
            # decode batch widths served here; a batched vmapped sampler
            # keyed on (temperature, top_k) groups is the upgrade path
            logits_np = np.asarray(logits)
            picked = {slot: self._sample(req, logits_np[slot])
                      for slot, req in running}
        for slot, req in running:
            tok = picked[slot]
            req.generated.append(tok)
            self.next_tok[slot] = tok
            events.append(StreamEvent(req.id, tok,
                                      len(req.generated) - 1, req.done))
        self.events_emitted += len(events)
        return events
