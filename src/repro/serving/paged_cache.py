"""Host-side paged KV cache manager.

The device side stores K/V in global page pools ``(Hkv, P, page_size, D)``
(one pool pair per attention layer, built by ``LM.init_paged_cache``);
this module owns the *bookkeeping*: a free list over physical pages and a
per-slot page table mapping logical KV block ``ki`` of the sequence in
decode slot ``b`` to its physical page.  ``page_size`` equals the decode
kernel's ``block_kv`` so one page table entry is exactly one kernel grid
step (the BlockSpec index map resolves ``ki -> table[b, ki]``).

Page 0 is reserved as a scratch page: idle decode slots keep an all-zero
table row and position 0, so their (ignored) writes land in scratch and
never touch pages owned by live sequences.

All state is plain numpy/int -- allocation runs on host between device
steps, the device only ever sees the int32 table snapshot.
"""
from __future__ import annotations

import numpy as np


class OutOfPages(RuntimeError):
    """Raised when an append needs a page and the free list is empty."""


def pages_needed(cur_len: int, new_len: int, page_size: int) -> int:
    """Pages to allocate when growing a sequence cur_len -> new_len."""
    cur_pages = -(-cur_len // page_size)
    new_pages = -(-new_len // page_size)
    return max(0, new_pages - cur_pages)


class PagedKVCache:
    """Free-list + page-table manager for ``num_pages`` physical pages of
    ``page_size`` tokens across ``max_slots`` decode slots."""

    SCRATCH = 0          # physical page 0: idle-slot write target, never owned

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 max_pages_per_seq: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_pages_per_seq = max_pages_per_seq
        # LIFO free list: recently freed pages are recycled first (their
        # contents are most likely still resident in any cache hierarchy).
        self._free = list(range(num_pages - 1, 0, -1))
        self._pages: list = [[] for _ in range(max_slots)]
        self._lens = np.zeros((max_slots,), np.int64)
        self._active = np.zeros((max_slots,), bool)
        self.table = np.zeros((max_slots, max_pages_per_seq), np.int32)
        self.peak_used_pages = 0

    # -- introspection -------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def seq_len(self, slot: int) -> int:
        return int(self._lens[slot])

    def seq_lens(self) -> np.ndarray:
        return self._lens.copy()

    def is_active(self, slot: int) -> bool:
        return bool(self._active[slot])

    def owned_pages(self, slot: int) -> list:
        return list(self._pages[slot])

    def capacity(self, slot: int) -> int:
        return len(self._pages[slot]) * self.page_size

    # -- logical -> physical -------------------------------------------
    def physical(self, slot: int, pos: int):
        """Map a logical token position to its (page, offset)."""
        if not self._active[slot] or pos >= self._lens[slot]:
            raise IndexError(f"slot {slot} pos {pos} not materialised")
        return self._pages[slot][pos // self.page_size], pos % self.page_size

    def device_table(self) -> np.ndarray:
        """int32 page-table snapshot for scalar prefetch (copy: the
        manager keeps mutating while the device step is in flight)."""
        return self.table.copy()

    def device_row(self, slot: int) -> np.ndarray:
        """One slot's (1, max_pages_per_seq) table snapshot -- what a
        single-sequence prefill chunk needs (avoids copying the whole
        table per chunk)."""
        return self.table[slot:slot + 1].copy()

    # -- alloc / append / free -----------------------------------------
    def alloc(self, slot: int) -> None:
        """Activate an empty slot (no pages yet -- append() materialises
        them lazily as tokens arrive)."""
        if self._active[slot]:
            raise ValueError(f"slot {slot} already active")
        self._active[slot] = True
        self._lens[slot] = 0

    def append(self, slot: int, n: int = 1) -> list:
        """Record ``n`` new tokens for ``slot``, allocating pages as the
        sequence crosses page boundaries.  Returns the newly materialised
        pages (empty when the tokens fit in the current tail page)."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        new_len = int(self._lens[slot]) + n
        need = pages_needed(int(self._lens[slot]), new_len, self.page_size)
        if -(-new_len // self.page_size) > self.max_pages_per_seq:
            raise OutOfPages(
                f"slot {slot}: {new_len} tokens exceeds "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        if need > len(self._free):
            raise OutOfPages(
                f"slot {slot}: need {need} pages, {len(self._free)} free")
        new_pages = []
        for _ in range(need):
            page = self._free.pop()
            self.table[slot, len(self._pages[slot])] = page
            self._pages[slot].append(page)
            new_pages.append(page)
        self._lens[slot] = new_len
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        return new_pages

    def free(self, slot: int) -> None:
        """Retire a slot: return its pages to the free list and reset its
        table row to scratch."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        self._free.extend(reversed(self._pages[slot]))
        self._pages[slot] = []
        self.table[slot, :] = self.SCRATCH
        self._lens[slot] = 0
        self._active[slot] = False

    # -- preemption / swap (page-pressure subsystem) --------------------
    def release_pages(self, slot: int) -> list:
        """Preempt a slot: deactivate it and return its pages to the free
        list.  Returns the page list it owned so the caller can account
        for them -- any contents worth keeping (swap-out) must have been
        copied off the device BEFORE this call, because the pages may be
        reallocated to another sequence immediately."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        pages = list(self._pages[slot])
        self.free(slot)
        return pages

    def adopt_pages(self, slot: int, n_tokens: int) -> list:
        """Swap-in: activate an empty slot and materialise pages for
        ``n_tokens`` in one shot.  Returns the new page list so the
        caller can scatter host-stashed KV back into them.  On
        OutOfPages the slot is left inactive (clean failure)."""
        self.alloc(slot)
        try:
            self.append(slot, n_tokens)
        except OutOfPages:
            self.free(slot)
            raise
        return list(self._pages[slot])

    @property
    def usable_pages(self) -> int:
        """Pages available to sequences (everything but scratch)."""
        return self.num_pages - 1

    @property
    def peak_utilization(self) -> float:
        """High-water mark as a fraction of the usable pool -- the number
        the over-subscription bench reports (worst-case-reservation
        admission keeps this well below 1; optimistic admission with
        preemption should push it to ~1)."""
        return self.peak_used_pages / max(1, self.usable_pages)

    # -- invariants (exercised by the property tests) -------------------
    def check_invariants(self) -> None:
        owned = [p for pages in self._pages for p in pages]
        assert self.SCRATCH not in owned, "scratch page was allocated"
        assert len(owned) == len(set(owned)), "page double-owned"
        assert not (set(owned) & set(self._free)), "page owned AND free"
        assert len(owned) + len(self._free) == self.num_pages - 1, \
            "page leaked"
        for slot in range(self.max_slots):
            have = len(self._pages[slot])
            assert have * self.page_size >= self._lens[slot], \
                f"slot {slot} under-allocated"
            assert (have - 1) * self.page_size < max(self._lens[slot], 1), \
                f"slot {slot} over-allocated"
            assert list(self.table[slot, :have]) == self._pages[slot], \
                f"slot {slot} table/page-list mismatch"
