"""Host-side paged KV cache manager.

The device side stores K/V in global page pools ``(Hkv, P, page_size, D)``
(one pool pair per attention layer, built by ``LM.init_paged_cache``);
this module owns the *bookkeeping*: a free list over physical pages and a
per-slot page table mapping logical KV block ``ki`` of the sequence in
decode slot ``b`` to its physical page.  ``page_size`` equals the decode
kernel's ``block_kv`` so one page table entry is exactly one kernel grid
step (the BlockSpec index map resolves ``ki -> table[b, ki]``).

Page 0 is reserved as a scratch page: idle decode slots keep an all-zero
table row and position 0, so their (ignored) writes land in scratch and
never touch pages owned by live sequences.

Pages carry **reference counts** so physical pages can be shared across
slots (prefix caching: several sequences with a common prompt prefix
read the same pages) and held by the radix prefix index after their
writer retires.  ``share_pages`` points an empty slot at already-resident
pages, ``incref``/``decref`` manage external (index) holds, and a page
only returns to the free list when its last reference drops.  Writes
stay safe via **copy-on-write**: ``append`` never writes into a
partially-filled tail page that is shared -- it moves the slot onto a
fresh copy first and records the (src, dst) pair in ``cow_pending`` so
the engine can replay the page copy on the device pools before the next
kernel launch.

All state is plain numpy/int -- allocation runs on host between device
steps, the device only ever sees the int32 table snapshot.
"""
from __future__ import annotations

import numpy as np


class OutOfPages(RuntimeError):
    """Raised when an append needs a page and the free list is empty."""


def pages_needed(cur_len: int, new_len: int, page_size: int) -> int:
    """Pages to allocate when growing a sequence cur_len -> new_len."""
    cur_pages = -(-cur_len // page_size)
    new_pages = -(-new_len // page_size)
    return max(0, new_pages - cur_pages)


class PagedKVCache:
    """Free-list + page-table manager for ``num_pages`` physical pages of
    ``page_size`` tokens across ``max_slots`` decode slots."""

    SCRATCH = 0          # physical page 0: idle-slot write target, never owned

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 max_pages_per_seq: int, *, injector=None, metrics=None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        # optional MetricsRegistry (serving/metrics.py): page allocations
        # and copy-on-write copies become cumulative counters
        self._c_alloc = (metrics.counter(
            "kv_pages_allocated_total",
            help="physical page allocations") if metrics is not None
            else None)
        self._c_cow = (metrics.counter(
            "kv_cow_copies_total",
            help="copy-on-write page copies") if metrics is not None
            else None)
        # optional FaultInjector (serving/faults.py): when armed, the
        # "page_alloc" site fires in append() BEFORE any mutation, so an
        # injected allocation fault leaves the cache untouched
        self.injector = injector
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_pages_per_seq = max_pages_per_seq
        # LIFO free list: recently freed pages are recycled first (their
        # contents are most likely still resident in any cache hierarchy).
        self._free = list(range(num_pages - 1, 0, -1))
        self._pages: list = [[] for _ in range(max_slots)]
        self._lens = np.zeros((max_slots,), np.int64)
        self._active = np.zeros((max_slots,), bool)
        self.table = np.zeros((max_slots, max_pages_per_seq), np.int32)
        self.peak_used_pages = 0
        # per-page reference count: one per slot listing the page plus one
        # per external hold (prefix index).  Free pages are 0; scratch is
        # never refcounted.
        self._ref = np.zeros((num_pages,), np.int64)
        # copy-on-write debts: (src, dst) physical page pairs whose
        # device contents the engine must copy before the next launch
        # that reads or writes dst.
        self.cow_pending: list = []

    # -- introspection -------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def seq_len(self, slot: int) -> int:
        return int(self._lens[slot])

    def seq_lens(self) -> np.ndarray:
        return self._lens.copy()

    def is_active(self, slot: int) -> bool:
        return bool(self._active[slot])

    def owned_pages(self, slot: int) -> list:
        return list(self._pages[slot])

    def capacity(self, slot: int) -> int:
        return len(self._pages[slot]) * self.page_size

    # -- logical -> physical -------------------------------------------
    def physical(self, slot: int, pos: int):
        """Map a logical token position to its (page, offset)."""
        if not self._active[slot] or pos >= self._lens[slot]:
            raise IndexError(f"slot {slot} pos {pos} not materialised")
        return self._pages[slot][pos // self.page_size], pos % self.page_size

    def device_table(self) -> np.ndarray:
        """int32 page-table snapshot for scalar prefetch (copy: the
        manager keeps mutating while the device step is in flight)."""
        return self.table.copy()

    def device_row(self, slot: int) -> np.ndarray:
        """One slot's (1, max_pages_per_seq) table snapshot -- what a
        single-sequence prefill chunk needs (avoids copying the whole
        table per chunk)."""
        return self.table[slot:slot + 1].copy()

    # -- reference counting (prefix sharing) ----------------------------
    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def incref(self, page: int) -> None:
        """Add a reference to a resident page (a page with no references
        may be reallocated at any moment, so incref'ing it is a bug)."""
        if page == self.SCRATCH:
            raise ValueError("scratch page cannot be referenced")
        if self._ref[page] <= 0:
            raise ValueError(f"page {page} is free, cannot incref")
        self._ref[page] += 1

    def decref(self, page: int) -> bool:
        """Drop a reference; returns True when the page's last reference
        fell and it went back to the free list."""
        if page == self.SCRATCH:
            raise ValueError("scratch page cannot be referenced")
        if self._ref[page] <= 0:
            raise ValueError(f"page {page} already free, cannot decref")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)
            return True
        return False

    def _take_free(self) -> int:
        page = self._free.pop()
        self._ref[page] = 1
        return page

    def share_pages(self, slot: int, pages: list, n_tokens: int) -> None:
        """Point an empty active slot at already-resident ``pages``
        (incref'ing each): the slot now reads ``n_tokens`` of KV it never
        computed.  ``n_tokens`` may stop short of the last page's
        capacity -- copy-on-write in ``append`` protects the shared tail
        from the slot's own writes."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        if self._pages[slot] or self._lens[slot]:
            raise ValueError(f"slot {slot} not empty")
        if not pages:
            raise ValueError("nothing to share")
        if len(pages) > self.max_pages_per_seq:
            raise ValueError(f"{len(pages)} pages exceeds max_pages_per_seq")
        if not ((len(pages) - 1) * self.page_size
                < n_tokens <= len(pages) * self.page_size):
            raise ValueError(
                f"n_tokens {n_tokens} inconsistent with {len(pages)} pages")
        for page in pages:
            self.incref(page)            # validates resident + not scratch
        for i, page in enumerate(pages):
            self.table[slot, i] = page
        self._pages[slot] = list(pages)
        self._lens[slot] = n_tokens

    # -- alloc / append / free -----------------------------------------
    def alloc(self, slot: int) -> None:
        """Activate an empty slot (no pages yet -- append() materialises
        them lazily as tokens arrive)."""
        if self._active[slot]:
            raise ValueError(f"slot {slot} already active")
        self._active[slot] = True
        self._lens[slot] = 0

    def append(self, slot: int, n: int = 1) -> list:
        """Record ``n`` new tokens for ``slot``, allocating pages as the
        sequence crosses page boundaries.  Returns the newly materialised
        pages (empty when the tokens fit in the current tail page).

        Copy-on-write: when the write would extend a partially-filled
        tail page that other references share, the slot is moved onto a
        fresh page first (old tail decref'd, (src, dst) recorded in
        ``cow_pending`` for the engine to replay on the device pools) --
        a shared page is never written through."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        cur = int(self._lens[slot])
        new_len = cur + n
        need = pages_needed(cur, new_len, self.page_size)
        cow = (n > 0 and cur % self.page_size != 0 and self._pages[slot]
               and self._ref[self._pages[slot][-1]] > 1)
        if -(-new_len // self.page_size) > self.max_pages_per_seq:
            raise OutOfPages(
                f"slot {slot}: {new_len} tokens exceeds "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        if need + (1 if cow else 0) > len(self._free):
            raise OutOfPages(
                f"slot {slot}: need {need + (1 if cow else 0)} pages, "
                f"{len(self._free)} free")
        if self.injector is not None and (need or cow):
            # fires before any mutation: a faulted append is a no-op
            self.injector.fire("page_alloc")
        if cow:
            old = self._pages[slot][-1]
            new = self._take_free()
            self._pages[slot][-1] = new
            self.table[slot, len(self._pages[slot]) - 1] = new
            self.decref(old)
            self.cow_pending.append((old, new))
            if self._c_cow is not None:
                self._c_cow.inc()
        new_pages = []
        for _ in range(need):
            page = self._take_free()
            self.table[slot, len(self._pages[slot])] = page
            self._pages[slot].append(page)
            new_pages.append(page)
        if (need or cow) and self._c_alloc is not None:
            self._c_alloc.inc(need + (1 if cow else 0))
        self._lens[slot] = new_len
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        return new_pages

    def truncate(self, slot: int, n_tokens: int) -> list:
        """Roll a slot back to ``n_tokens`` (speculative-decode rejection:
        drafted rows past the accept point are discarded).  Whole tail
        pages the shorter sequence no longer covers are decref'd -- a
        page shared with another slot or the prefix index stays resident
        for its other holders -- and their table entries reset to
        scratch.  Pending copy-on-write debts whose destination page
        just went back to the free list are cancelled, exactly like
        ``scheduler.abort`` (a freed page may be reallocated before the
        replay runs).  Returns the dropped pages.  Stale rows left in
        the kept tail page need no device-side cleanup: the paged
        kernels mask by sequence length and the next append overwrites
        them."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        cur = int(self._lens[slot])
        if not 0 <= n_tokens <= cur:
            raise ValueError(
                f"slot {slot}: truncate to {n_tokens} outside [0, {cur}]")
        keep = -(-n_tokens // self.page_size)
        dropped = self._pages[slot][keep:]
        freed = set()
        for page in reversed(dropped):
            if self.decref(page):
                freed.add(page)
        del self._pages[slot][keep:]
        self.table[slot, keep:] = self.SCRATCH
        self._lens[slot] = n_tokens
        if freed and self.cow_pending:
            self.cow_pending = [(s, d) for s, d in self.cow_pending
                                if d not in freed]
        return dropped

    def free(self, slot: int) -> None:
        """Retire a slot: drop its reference on every page (pages whose
        last reference falls return to the free list) and reset its
        table row to scratch."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        for page in reversed(self._pages[slot]):
            self.decref(page)
        self._pages[slot] = []
        self.table[slot, :] = self.SCRATCH
        self._lens[slot] = 0
        self._active[slot] = False

    # -- preemption / swap (page-pressure subsystem) --------------------
    def release_pages(self, slot: int) -> list:
        """Preempt a slot: deactivate it and drop its page references
        (exclusive pages return to the free list; shared pages stay
        resident for their other holders).  Returns the page list it
        held so the caller can account for them -- any refcount-1
        contents worth keeping (swap-out) must have been copied off the
        device BEFORE this call, because freed pages may be reallocated
        to another sequence immediately."""
        if not self._active[slot]:
            raise ValueError(f"slot {slot} not active")
        pages = list(self._pages[slot])
        self.free(slot)
        return pages

    def adopt_pages(self, slot: int, n_tokens: int) -> list:
        """Swap-in: activate an empty slot and materialise pages for
        ``n_tokens`` in one shot.  Returns the new page list so the
        caller can scatter host-stashed KV back into them.  On
        OutOfPages the slot is left inactive (clean failure)."""
        self.alloc(slot)
        try:
            self.append(slot, n_tokens)
        except OutOfPages:
            self.free(slot)
            raise
        return list(self._pages[slot])

    @property
    def usable_pages(self) -> int:
        """Pages available to sequences (everything but scratch)."""
        return self.num_pages - 1

    @property
    def peak_utilization(self) -> float:
        """High-water mark as a fraction of the usable pool -- the number
        the over-subscription bench reports (worst-case-reservation
        admission keeps this well below 1; optimistic admission with
        preemption should push it to ~1)."""
        return self.peak_used_pages / max(1, self.usable_pages)

    def reset_peak(self) -> None:
        """Re-arm the high-water mark at the *current* usage (not zero:
        pages already resident -- live sequences, cached prefixes -- are
        part of any peak observed from here on).  Called by
        ``EngineCore.reset_metrics_window()`` so bench warmups do not
        pollute the timed region's peak."""
        self.peak_used_pages = self.used_pages

    # -- invariants (exercised by the property tests) -------------------
    def check_invariants(self, extern_refs: dict = None) -> None:
        """``extern_refs``: page -> count of references held outside any
        slot (the prefix index's holds).  When given, every page's
        refcount must be exactly its slot references plus its external
        references; without it, only ``refcount >= slot references`` can
        be (and is) asserted."""
        slot_refs = np.zeros((self.num_pages,), np.int64)
        for pages in self._pages:
            assert len(pages) == len(set(pages)), \
                "page listed twice by one slot"
            for p in pages:
                slot_refs[p] += 1
        assert slot_refs[self.SCRATCH] == 0, "scratch page was allocated"
        assert self._ref[self.SCRATCH] == 0, "scratch page refcounted"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list duplicate"
        assert self.SCRATCH not in free_set, "scratch page freed"
        for p in range(1, self.num_pages):
            if p in free_set:
                assert self._ref[p] == 0, f"page {p} free with refs"
                assert slot_refs[p] == 0, f"page {p} owned AND free"
            else:
                assert self._ref[p] > 0, f"page {p} leaked (no refs)"
                assert self._ref[p] >= slot_refs[p], \
                    f"page {p} refcount below its slot references"
                if extern_refs is not None:
                    assert self._ref[p] == slot_refs[p] + \
                        extern_refs.get(p, 0), \
                        f"page {p} refcount does not balance"
        if extern_refs is not None:
            for p, n in extern_refs.items():
                assert n > 0 and self._ref[p] >= n, \
                    f"external hold on page {p} unbacked"
        for slot in range(self.max_slots):
            have = len(self._pages[slot])
            assert have * self.page_size >= self._lens[slot], \
                f"slot {slot} under-allocated"
            assert (have - 1) * self.page_size < max(self._lens[slot], 1), \
                f"slot {slot} over-allocated"
            assert list(self.table[slot, :have]) == self._pages[slot], \
                f"slot {slot} table/page-list mismatch"
