"""Page-pressure manager: preemption with KV swap-to-host or recompute.

The paper's §4.4 CPU-GPU cooperative strategy moves KV to the host when
device memory runs out instead of refusing the request; this module is
that idea applied to the paged serving engine.  Optimistic admission
(``scheduler.admit``) no longer reserves worst-case pages, so
``PagedKVCache.append`` can legitimately hit ``OutOfPages`` mid-step.
The engine then calls ``PressureManager.relieve``, which evicts the
newest-admitted sequence (``scheduler.preemption_victim``) and disposes
of its materialised KV one of two ways:

* **swap** -- the victim's page-table rows are gathered off the device
  pools into a ``HostPagePool`` stash (device->host copy); on resume the
  scheduler re-materialises pages (``adopt_pages``) and the engine
  scatters the stash back.  The round trip is bit-exact, so greedy
  tokens are identical to an unpressured run.
* **recompute** -- nothing is copied; on resume the sequence re-prefills
  ``prompt + generated[:-1]`` through the existing chunked paged prefill
  (bit-identical KV by the PR 2 chunked==scan==decode equivalence).

``preempt_policy="auto"`` chooses per victim with the PCIe/FLOPs cost
model built on ``core/offload.py``'s paper-calibrated constants: swap
pays a fixed transfer latency plus bytes over effective PCIe both ways,
recompute pays ~2*params FLOPs per token -- so small victims recompute
and long-context victims swap.

All device data movement is eager host-side numpy/jnp between engine
steps; the jitted decode/prefill functions never see any of this.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core.offload import OffloadLatencyModel, preempt_cost_model
from repro.serving.faults import SwapRestoreFailed
from repro.serving.paged_cache import OutOfPages, PagedKVCache
from repro.serving.scheduler import (PREFILLING, ContinuousBatchScheduler,
                                     Request)

# Pool leaves are (..., num_pages, page_size, head_dim): plain per-layer
# pools are 4-D (Hkv, P, ps, D), lax.scan-stacked segments are 5-D
# (reps, Hkv, P, ps, D) -- the page axis is always third from the end.
PAGE_AXIS_FROM_END = 3


def gather_pages(pools, pages) -> dict:
    """Device->host copy of the given physical pages from every pool
    leaf.  Returns a pytree of numpy arrays shaped like the leaves with
    the page axis narrowed to ``len(pages)``."""
    idx = jnp.asarray(np.asarray(pages, np.int32))
    return jax.tree.map(
        lambda a: np.asarray(jnp.take(a, idx,
                                      axis=a.ndim - PAGE_AXIS_FROM_END)),
        pools)


def _scatter_impl(pools, idx, host_data):
    def put(a, h):
        sl = (slice(None),) * (a.ndim - PAGE_AXIS_FROM_END) + (idx,)
        return a.at[sl].set(h.astype(a.dtype))

    return jax.tree.map(put, pools, host_data)


# jitted with the pools donated so XLA updates the pages in place --
# an eager .at[].set would materialise a full copy of every per-layer
# pool (the whole KV budget) per restored victim.  Donation is skipped
# on CPU where it is unsupported (and would only warn).
_scatter_jit = jax.jit(
    _scatter_impl,
    donate_argnums=(0,) if jax.default_backend() != "cpu" else ())


def scatter_pages(pools, pages, host_data):
    """Host->device copy-back: write ``host_data`` (a ``gather_pages``
    result) into the -- possibly different -- physical ``pages`` of every
    pool leaf.  Same dtype both ways, so the swap round trip is exact.
    Retraces once per distinct victim page count (bounded by
    ``max_pages_per_seq``), not per restore."""
    idx = jnp.asarray(np.asarray(pages, np.int32))
    return _scatter_jit(pools, idx,
                        jax.tree.map(jnp.asarray, host_data))


def _copy_impl(pools, src, dst):
    def cp(a):
        axis = a.ndim - PAGE_AXIS_FROM_END
        data = jnp.take(a, src, axis=axis)
        sl = (slice(None),) * axis + (dst,)
        return a.at[sl].set(data)

    return jax.tree.map(cp, pools)


_copy_jit = jax.jit(
    _copy_impl,
    donate_argnums=(0,) if jax.default_backend() != "cpu" else ())


def copy_pages(pools, srcs, dsts):
    """Device-side page copy (``dst[i] <- src[i]`` in every pool leaf):
    the data half of copy-on-write -- the host manager moved a slot off
    a shared tail page, this replays the contents onto the fresh copy
    before the next launch writes it.  All sources are read before any
    destination is written (parallel-copy semantics)."""
    return _copy_jit(pools, jnp.asarray(np.asarray(srcs, np.int32)),
                     jnp.asarray(np.asarray(dsts, np.int32)))


def _nbytes(tree) -> int:
    return sum(a.nbytes for a in jax.tree.leaves(tree))


class HostPagePool:
    """Host-side stash for swapped-out KV pages, keyed by request id.

    ``capacity_pages == 0`` means unbounded (host RAM is the real bound,
    cf. the paper's 768 GB host vs 8x16 GB devices)."""

    def __init__(self, capacity_pages: int = 0):
        self.capacity_pages = capacity_pages
        self.used_pages = 0
        self.peak_pages = 0
        self._stash: dict = {}          # request id -> (host_tree, n_pages)

    def has_room(self, n_pages: int) -> bool:
        return (not self.capacity_pages
                or self.used_pages + n_pages <= self.capacity_pages)

    def put(self, request_id: int, host_data, n_pages: int) -> None:
        if request_id in self._stash:
            raise ValueError(f"request {request_id} already stashed")
        if not self.has_room(n_pages):
            raise OutOfPages(
                f"host page pool full: {self.used_pages}+{n_pages} > "
                f"{self.capacity_pages}")
        self._stash[request_id] = (host_data, n_pages)
        self.used_pages += n_pages
        self.peak_pages = max(self.peak_pages, self.used_pages)

    def peek(self, request_id: int):
        """Read a stash without consuming it -- restore() scatters from
        a peek and only pops after the copy-back succeeded, so a failed
        swap-in never loses the only copy of the KV."""
        return self._stash[request_id][0]

    def pop(self, request_id: int):
        host_data, n_pages = self._stash.pop(request_id)
        self.used_pages -= n_pages
        return host_data

    def __contains__(self, request_id: int) -> bool:
        return request_id in self._stash

    def __len__(self) -> int:
        return len(self._stash)


class PressureManager:
    """Relieves ``OutOfPages`` by evicting sequences, and restores them
    on re-admission.  Owns the host page pool, the swap/recompute policy
    and the pressure statistics the bench reports."""

    def __init__(self, cfg: ModelConfig, serve: ServeConfig,
                 cache: PagedKVCache, sched: ContinuousBatchScheduler, *,
                 latency_model: Optional[OffloadLatencyModel] = None,
                 swap_latency_s: float = 5e-4, prefix_cache=None,
                 injector=None, metrics=None, tracer=None):
        if serve.preempt_policy not in ("swap", "recompute", "auto"):
            raise ValueError(
                f"unknown preempt_policy {serve.preempt_policy!r}")
        self.cfg = cfg
        self.cache = cache
        self.sched = sched
        self.policy = serve.preempt_policy
        self.host_pool = HostPagePool(serve.host_pool_pages)
        self.lat = latency_model or OffloadLatencyModel()
        self.swap_latency_s = swap_latency_s
        self.dtype_bytes = jnp.dtype(cfg.dtype).itemsize
        self.prefix_cache = prefix_cache    # RadixPrefixIndex or None
        self.injector = injector            # FaultInjector or None
        self.swap_retries = serve.swap_retries
        self.swap_retry_backoff_s = serve.swap_retry_backoff_s
        self.stats = {"preemptions": 0, "swaps": 0, "recomputes": 0,
                      "swap_bytes_out": 0, "swap_bytes_in": 0,
                      "cache_evictions": 0, "swap_drops": 0,
                      "abort_drops": 0, "fail_drops": 0,
                      "swap_retries": 0, "swap_fail_downgrades": 0}
        # telemetry (serving/metrics.py): the stats dict stays the
        # authority stats() exposes; a registry mirrors every key as a
        # cumulative ``pressure_<key>_total`` counter, and the tracer
        # sees swap-out/in/drop so the "swapped" span closes exactly
        # when the stash dies
        self.metrics = metrics
        self.tracer = tracer
        self._counters = ({k: metrics.counter(f"pressure_{k}_total")
                           for k in self.stats}
                          if metrics is not None else None)

    def _bump(self, key: str, n: int = 1) -> None:
        self.stats[key] += n
        if self._counters is not None:
            self._counters[key].inc(n)

    # -- transient-fault retry --------------------------------------------
    def _swap_op(self, site: str, fn):
        """Run a swap DMA op under the transient-fault retry budget:
        ``swap_retries`` retries with bounded exponential backoff.  The
        injector site fires BEFORE the op, so an injected fault never
        leaves a half-done copy.  Returns the op's result, or None when
        the budget is exhausted -- the caller downgrades to recompute
        (swap-out) or raises SwapRestoreFailed (swap-in); a swap fault
        never fails the request itself.  OutOfPages is not a transient
        fault and passes straight through."""
        for attempt in range(self.swap_retries + 1):
            try:
                if self.injector is not None:
                    self.injector.fire(site)
                return fn()
            except OutOfPages:
                raise
            except RuntimeError:            # InjectedFault or real DMA error
                self._bump("swap_retries")
                if attempt < self.swap_retries \
                        and self.swap_retry_backoff_s > 0:
                    time.sleep(min(self.swap_retry_backoff_s * 2 ** attempt,
                                   0.1))
        return None

    # -- policy ----------------------------------------------------------
    def choose_policy(self, n_pages: int, n_tokens: int) -> str:
        """Swap vs recompute for a victim with ``n_pages`` materialised
        pages / ``n_tokens`` tokens (before the host-pool room check)."""
        if n_tokens == 0 or self.policy == "recompute":
            return "recompute"
        if self.policy == "swap":
            return "swap"
        swap_s, rec_s = preempt_cost_model(
            self.cfg, n_pages=n_pages, n_tokens=n_tokens,
            page_size=self.cache.page_size, model=self.lat,
            dtype_bytes=self.dtype_bytes,
            swap_latency_s=self.swap_latency_s)
        return "swap" if swap_s < rec_s else "recompute"

    # -- evict -----------------------------------------------------------
    def relieve(self, pools, protect: Optional[int] = None
                ) -> Optional[Request]:
        """Free at least one page: first reclaim an LRU leaf from the
        prefix index (cached-but-idle KV goes before live sequences),
        else evict the newest-admitted sequence other than ``protect``.
        Returns the preempted request, or None when index eviction
        sufficed.  Raises OutOfPages when nothing is reclaimable (cannot
        happen for pool-validated requests: the protected slot alone
        always fits an otherwise-empty pool)."""
        if self.prefix_cache is not None and self.prefix_cache.evict(1):
            self._bump("cache_evictions")
            return None
        victim = self.sched.preemption_victim(protect)
        if victim is None:
            raise OutOfPages(
                "page pressure with no preemptible sequence -- pool too "
                "small for a single request (submit-time validation "
                "should have rejected it)")
        return self.preempt_slot(pools, victim)

    def preempt_slot(self, pools, slot: int) -> Request:
        """Evict a specific slot: decide swap/recompute over its
        *exclusive* pages, copy those off the device if swapping, then
        hand the slot back to the scheduler.  Pages shared with other
        slots or the prefix index (always a contiguous page-list prefix:
        sharers and the index both hold block prefixes) are only
        decref'd -- never swapped, never freed from under a sharer --
        and re-shared at resume."""
        req = self.sched.slots[slot]
        # KV actually written to the pools: a PREFILLING victim has its
        # completed chunks; a decoding victim has prompt + all generated
        # tokens but the last (whose KV its next decode step writes).
        written = req.prefilled if req.state == PREFILLING \
            else req.prefill_total
        ps = self.cache.page_size
        n_pages = -(-written // ps)
        owned = self.cache.owned_pages(slot)[:n_pages]
        shared = 0
        while shared < len(owned) \
                and self.cache.refcount(owned[shared]) > 1:
            shared += 1
        shared_len = min(shared * ps, written)
        kind = self.choose_policy(n_pages - shared, written - shared_len)
        if kind == "swap" and not self.host_pool.has_room(n_pages - shared):
            kind = "recompute"
        if kind == "swap":
            host_data = self._swap_op(
                "swap_d2h", lambda: gather_pages(pools, owned[shared:]))
            if host_data is None:
                # D2H kept failing past the retry budget: fall back to
                # recompute -- strictly slower, never incorrect
                kind = "recompute"
                self._bump("swap_fail_downgrades")
        if kind == "swap":
            self.host_pool.put(req.id, host_data, n_pages - shared)
            self._bump("swaps")
            self._bump("swap_bytes_out", _nbytes(host_data))
            req.resume_shared_len = shared_len
            if self.tracer is not None:
                self.tracer.on_swap_out(req)
        else:
            self._bump("recomputes")
            req.resume_shared_len = 0
        req.resume_kind = kind
        req.resume_len = written
        self.sched.preempt(slot)
        self._bump("preemptions")
        return req

    # -- restore ---------------------------------------------------------
    def holds(self, request_id: int) -> bool:
        return request_id in self.host_pool

    def restore(self, pools, slot: int, req: Request):
        """Copy a swap-resumed request's stashed KV back into the pages
        admission just materialised for it -- the exclusive suffix only;
        the shared prefix was re-shared straight from the index.
        Returns new pools.  The scatter reads from a ``peek`` of the
        stash and only pops it once the copy-back succeeded; past the
        retry budget this raises ``SwapRestoreFailed`` with the stash
        intact, and the engine downgrades the resume to recompute."""
        host_data = self.host_pool.peek(req.id)
        ps = self.cache.page_size
        n_pages = -(-req.resume_len // ps)
        k = req.resume_shared_len // ps
        pages = self.cache.owned_pages(slot)[k:n_pages]
        assert len(pages) == n_pages - k, (slot, pages, n_pages, k)
        new_pools = self._swap_op(
            "swap_h2d", lambda: scatter_pages(pools, pages, host_data))
        if new_pools is None:
            raise SwapRestoreFailed(
                f"request {req.id}: swap-in failed past "
                f"{self.swap_retries} retries")
        self.host_pool.pop(req.id)
        self._bump("swap_bytes_in", _nbytes(host_data))
        req.resume_kind = None
        req.resume_shared_len = 0
        if self.tracer is not None:
            self.tracer.on_swap_in(req)
        return new_pools

    def drop(self, request_id: int, *, reason: str = "downgrade") -> None:
        """Discard a stash: its owner was downgraded to recompute while
        waiting (its shared prefix got evicted, so the exclusive-suffix
        stash alone no longer reconstructs the sequence), aborted while
        swap-preempted (``reason="abort"``), or quarantined after a
        request-level failure (``reason="fail"``)."""
        self.host_pool.pop(request_id)
        self._bump({"abort": "abort_drops",
                    "fail": "fail_drops"}.get(reason, "swap_drops"))
        if self.tracer is not None:
            self.tracer.on_swap_drop(request_id)
