"""Serving engine: dense prefill+decode, and the paged streaming shim.

The dense path (``prefill``/``generate``) teacher-forces the prompt
through decode steps in a scanned loop and is unchanged from the early
PRs -- it remains the oracle the paged path is tested against.

The multi-tenant paged path now lives in :mod:`repro.serving.core`:
``EngineCore`` is a *persistent* iteration-level engine
(``add_request``/``step``/``abort``/``reset``/``stats``) owning the page
manager, scheduler, pressure manager, radix prefix index, device pools
and jitted functions across calls.  ``ServeEngine.generate_stream`` is
kept as a thin compatibility wrapper: it submits the batch of requests
to the engine's core, drains ``step()`` while any of them is live, and
aborts the leftovers when the caller abandons the generator -- greedy
output is bit-identical to the pre-core engine.  New code should drive
``ServeEngine.core`` (or an ``EngineCore`` directly) and pass
``SamplingParams`` per request.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core.offload import HostOffloadEngine
# Re-exported for backward compatibility: these used to be defined here.
from repro.serving.core import EngineCore, StreamEvent, sample_token  # noqa: F401
from repro.serving.scheduler import (ABORTED, FAILED, FINISHED, Request,
                                     SamplingParams)  # noqa: F401


class _StreamDrain:
    """Iterator over one generate_stream call's events.  A plain
    generator's ``finally`` never runs when the generator is dropped
    before its first ``next()`` -- but this call's requests are already
    queued on the persistent core and its routing entry registered, so
    cleanup (unregister, abort leftovers) must run regardless.  This
    wrapper guarantees it via ``close()``/``__del__``."""

    def __init__(self, gen, cleanup):
        self._gen = gen
        self._cleanup = cleanup

    def __iter__(self):
        return self

    def __next__(self):
        return next(self._gen)

    def close(self):
        try:
            self._gen.close()
        finally:
            self._cleanup()

    def __del__(self):
        try:
            self.close()
        # finalizer: the interpreter may be tearing down, nothing to
        # feed the fault taxonomy here
        except Exception:  # repro-lint: disable=swallowed-exception
            pass


def _seed_offset(key) -> int:
    """Legacy ``generate_stream(key=...)`` support: per-request counter
    RNG supersedes the stream-global key, which now only offsets the
    seeds derived for requests submitted without SamplingParams."""
    if key is None:
        return 0
    try:
        data = jax.random.key_data(key)
    except (AttributeError, TypeError):
        data = key
    return int(np.asarray(data).ravel()[-1])


@dataclass
class ServeEngine:
    model: object
    params: dict
    cfg: ModelConfig
    serve: ServeConfig = field(default_factory=ServeConfig)
    offload: Optional[HostOffloadEngine] = None
    # token-ids -> text callable, forwarded to the core; required only
    # when requests carry SamplingParams.stop_strings
    detokenize: Optional[object] = None
    # FaultInjector (serving/faults.py) forwarded to the core; None is
    # the no-op default
    injector: Optional[object] = None
    # jitted paged prefill/decode triples keyed by resolved paged impl;
    # the same dict object backs the core, so tests clearing it force a
    # retrace through both
    _paged_fn_cache: dict = field(default_factory=dict, repr=False)
    _core: Optional[EngineCore] = field(default=None, repr=False)
    # live generate_stream drains: (id set, event buffer) per call, so
    # interleaved streams on the one shared core route -- not drop --
    # each other's tokens
    _stream_subs: list = field(default_factory=list, repr=False)
    # injectable clock shared with the core: both the wrapper's measured
    # durations (throughput_tokens_per_s) and EngineCore._clock read the
    # same function, so frozen-clock tests cover wrapper timing too
    clock: Optional[object] = None

    def __post_init__(self):
        self._clock = self.clock or time.monotonic
        self._decode = jax.jit(
            lambda p, t, c, pos: self.model.decode_step(p, t, c, pos),
            donate_argnums=(2,))   # KV cache updated in place

    # ------------------------------------------------------------------
    # the persistent core (paged serving state lives there)
    # ------------------------------------------------------------------
    @property
    def core(self) -> EngineCore:
        """The engine's persistent ``EngineCore`` (created on first
        use).  Page manager, scheduler, pressure manager, prefix index,
        device pools and jit caches all live on it, across calls."""
        if self._core is None:
            self._core = EngineCore(self.model, self.params, self.cfg,
                                    self.serve,
                                    fn_cache=self._paged_fn_cache,
                                    detokenize=self.detokenize,
                                    injector=self.injector,
                                    clock=self._clock)
        return self._core

    # Back-compat observability aliases: benchmarks/tests read these off
    # the engine after (or during) a stream.  They now resolve to the
    # persistent core's live objects.
    @property
    def last_cache(self):
        return self.core.mgr

    @property
    def last_scheduler(self):
        return self.core.sched

    @property
    def last_pressure(self):
        return self.core.pressure

    @property
    def last_prefix(self):
        return self.core.prefix

    @property
    def metrics(self):
        """The core's MetricsRegistry (serving/metrics.py)."""
        return self.core.metrics

    @property
    def prefill_launches(self) -> int:
        return self.core.prefill_launches

    @prefill_launches.setter
    def prefill_launches(self, value: int) -> None:
        self.core.prefill_launches = value

    @property
    def prefill_trace_count(self) -> int:
        return self.core.prefill_trace_count

    @prefill_trace_count.setter
    def prefill_trace_count(self, value: int) -> None:
        self.core.prefill_trace_count = value

    # ------------------------------------------------------------------
    # dense (static-batch) path
    # ------------------------------------------------------------------
    def prefill(self, tokens: jax.Array):
        """tokens: (B, S_prompt).  Returns (cache, last_logits)."""
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.serve.max_seq_len)

        # scan over prompt positions (jit'd once)
        def scan_fn(params, tokens, cache):
            def step(c, t):
                lg, c = self.model.decode_step(params, tokens[:, t], c, t)
                return c, lg
            return jax.lax.scan(step, cache, jnp.arange(s))

        cache, all_logits = jax.jit(scan_fn)(self.params, tokens, cache)
        return cache, all_logits[-1]

    def generate(self, tokens: jax.Array, n_new: int,
                 key: Optional[jax.Array] = None):
        """Greedy/top-k generation.  Returns (B, n_new) tokens."""
        key = key if key is not None else jax.random.PRNGKey(self.serve.seed)
        b, s = tokens.shape
        cache, logits = self.prefill(tokens)
        out = []
        tok = sample_token(logits, key, temperature=self.serve.temperature,
                           top_k=self.serve.top_k)
        out.append(tok)
        for i in range(1, n_new):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok, cache, s + i - 1)
            tok = sample_token(logits, sub,
                               temperature=self.serve.temperature,
                               top_k=self.serve.top_k)
            out.append(tok)
        return jnp.stack(out, axis=1)

    # ------------------------------------------------------------------
    # paged KV + continuous batching (compatibility shim over EngineCore)
    # ------------------------------------------------------------------
    def generate_stream(self, requests: Iterable[Request],
                        key: Optional[jax.Array] = None):
        """Continuous-batching generation over the persistent core.

        Submits ``requests`` (scheduler.Request objects -- any number,
        they queue) to ``self.core`` and yields
        StreamEvent(request_id, token, index, finished) as ``step()``
        produces tokens, until every submitted request finished or
        aborted.  Abandoning the generator aborts this call's live
        requests -- their pages are freed, shared prefix pages just drop
        one reference, and the core keeps serving.
        """
        core = self.core
        offset = _seed_offset(key)
        # submit (and validate) eagerly, at the call site: the drain loop
        # is a generator and would otherwise defer errors to first next().
        # On a mid-batch failure, un-queue this call's earlier submissions
        # -- the core persists, a rejected batch must not leave strays.
        submitted = []
        try:
            for r in requests:
                submitted.append(core.submit_request(r, seed_offset=offset))
        except Exception:
            for r in submitted:
                core.abort(r.id)
            raise

        buf: deque = deque()
        sub = ({r.id for r in submitted}, buf)
        subs = self._stream_subs
        # register eagerly: interleaved drains on the one shared core may
        # step out this call's tokens before its generator is first
        # advanced -- they must land in this buffer, in production order
        subs.append(sub)

        def dispatch(events):
            # route every stepped event to its call's buffer; events of
            # requests no drain owns (direct add_request users) are
            # recoverable from core.orphan_events
            for ev in events:
                for other_ids, other_buf in subs:
                    if ev.request_id in other_ids:
                        other_buf.append(ev)
                        break
                else:
                    core.orphan_events.append(ev)

        cleaned = False

        def cleanup():
            nonlocal cleaned
            if cleaned:
                return
            cleaned = True
            subs.remove(sub)
            for r in submitted:
                if r.state not in (FINISHED, ABORTED, FAILED):
                    core.abort(r.id)

        def drain():
            try:
                while True:
                    while buf:          # may refill while we yield
                        yield buf.popleft()
                    if all(r.state in (FINISHED, ABORTED, FAILED)
                           for r in submitted):
                        break
                    dispatch(core.step())
                while buf:
                    yield buf.popleft()
            finally:
                cleanup()

        return _StreamDrain(drain(), cleanup)

    def throughput_tokens_per_s(self, batch: int, prompt_len: int,
                                n_new: int = 8) -> float:
        """Measured decode throughput (benchmark helper).  Durations
        are read off the engine's injectable clock (``self._clock``),
        so a manual clock makes the reported rate deterministic."""
        tokens = jnp.zeros((batch, prompt_len), jnp.int32)
        cache, logits = self.prefill(tokens)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        # warmup + timed loop
        logits, cache = self._decode(self.params, tok, cache, prompt_len)
        jax.block_until_ready(logits)
        t0 = self._clock()
        for i in range(n_new):
            logits, cache = self._decode(self.params, tok, cache,
                                         prompt_len + 1 + i)
        jax.block_until_ready(logits)
        dt = self._clock() - t0
        return batch * n_new / dt
