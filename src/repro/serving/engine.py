"""Serving engine: prefill + decode loop with greedy/top-k sampling and
optional T4 host offload of the KV cache.

Prefill fills the cache by teacher-forcing the prompt through decode steps
in a scanned loop (exactly matches the training forward -- verified by the
decode-vs-prefill consistency tests); with `chunked_prefill` the prompt is
instead processed in chunks through the full forward using q_offset, the
paper-faithful fast path.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig, ServeConfig
from repro.core.offload import HostOffloadEngine, OffloadPlan, plan_offload


def sample_token(logits, key, *, temperature: float = 1.0, top_k: int = 0):
    if temperature == 0.0 or top_k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 1:
        vals, _ = jax.lax.top_k(lf, top_k)
        thresh = vals[..., -1:]
        lf = jnp.where(lf < thresh, -1e30, lf)
    return jax.random.categorical(key, lf).astype(jnp.int32)


@dataclass
class ServeEngine:
    model: object
    params: dict
    cfg: ModelConfig
    serve: ServeConfig = ServeConfig()
    offload: Optional[HostOffloadEngine] = None

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, t, c, pos: self.model.decode_step(p, t, c, pos),
            donate_argnums=(2,))   # KV cache updated in place

    # ------------------------------------------------------------------
    def prefill(self, tokens: jax.Array):
        """tokens: (B, S_prompt).  Returns (cache, last_logits)."""
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.serve.max_seq_len)
        logits = None

        def body(carry, t):
            cache = carry
            lg, cache = self.model.decode_step(
                self.params, tokens[:, t], cache, t)
            return cache, lg

        # scan over prompt positions (jit'd once)
        def scan_fn(params, tokens, cache):
            def step(c, t):
                lg, c = self.model.decode_step(params, tokens[:, t], c, t)
                return c, lg
            return jax.lax.scan(step, cache, jnp.arange(s))

        cache, all_logits = jax.jit(scan_fn)(self.params, tokens, cache)
        return cache, all_logits[-1]

    def generate(self, tokens: jax.Array, n_new: int,
                 key: Optional[jax.Array] = None):
        """Greedy/top-k generation.  Returns (B, n_new) tokens."""
        key = key if key is not None else jax.random.PRNGKey(self.serve.seed)
        b, s = tokens.shape
        cache, logits = self.prefill(tokens)
        out = []
        tok = sample_token(logits, key, temperature=self.serve.temperature,
                           top_k=self.serve.top_k)
        out.append(tok)
        for i in range(1, n_new):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok, cache, s + i - 1)
            tok = sample_token(logits, sub,
                               temperature=self.serve.temperature,
                               top_k=self.serve.top_k)
            out.append(tok)
        return jnp.stack(out, axis=1)

    def throughput_tokens_per_s(self, batch: int, prompt_len: int,
                                n_new: int = 8) -> float:
        """Measured decode throughput (benchmark helper)."""
        import time
        tokens = jnp.zeros((batch, prompt_len), jnp.int32)
        cache, logits = self.prefill(tokens)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        # warmup + timed loop
        logits, cache = self._decode(self.params, tok, cache, prompt_len)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for i in range(n_new):
            logits, cache = self._decode(self.params, tok, cache,
                                         prompt_len + 1 + i)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return batch * n_new / dt
