"""Serving engine: prefill + decode loop with greedy/top-k sampling and
optional T4 host offload of the KV cache.

Prefill fills the cache by teacher-forcing the prompt through decode steps
in a scanned loop (exactly matches the training forward -- verified by the
decode-vs-prefill consistency tests); with `chunked_prefill` the prompt is
instead processed in chunks through the full forward using q_offset, the
paper-faithful fast path.

``generate_stream`` is the multi-tenant path: paged KV cache + continuous
batching.  Sequences share global page pools, a host-side scheduler admits
and retires requests every step, and tokens stream out per request as they
are produced -- no sequence waits for the batch.  Prompts are prefilled in
fixed ``prefill_chunk`` token chunks through the full transformer forward
(the paper's tiled prefill kernel with runtime q offsets) interleaved with
decode steps under a ``prefill_token_budget``, so a long newcomer never
stalls the tokens of running sequences and time-to-first-token is
O(prompt/chunk) kernel launches instead of O(prompt) decode steps.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig, ServeConfig
from repro.core.fastattention import default_paged_impl
from repro.core.offload import HostOffloadEngine, OffloadPlan, plan_offload
from repro.serving.paged_cache import OutOfPages, PagedKVCache
from repro.serving.prefix_cache import RadixPrefixIndex
from repro.serving.pressure import PressureManager, copy_pages
from repro.serving.scheduler import (PREFILLING, RUNNING,
                                     ContinuousBatchScheduler, Request)


def sample_token(logits, key, *, temperature: float = 1.0, top_k: int = 0):
    if temperature == 0.0 or top_k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 1:
        # lax.top_k rejects k > vocab; clamping makes oversized k mean
        # "no truncation" instead of a crash
        k = min(top_k, lf.shape[-1])
        vals, _ = jax.lax.top_k(lf, k)
        thresh = vals[..., -1:]
        lf = jnp.where(lf < thresh, -1e30, lf)
    return jax.random.categorical(key, lf).astype(jnp.int32)


class StreamEvent(NamedTuple):
    """One generated token, streamed as soon as it exists."""
    request_id: int
    token: int
    index: int            # position within the request's generation
    finished: bool        # True on the request's last token


@dataclass
class ServeEngine:
    model: object
    params: dict
    cfg: ModelConfig
    serve: ServeConfig = field(default_factory=ServeConfig)
    offload: Optional[HostOffloadEngine] = None
    # jitted paged prefill/decode triples keyed by resolved paged impl
    _paged_fn_cache: dict = field(default_factory=dict, repr=False)
    # paged state persisted across generate_stream calls when the prefix
    # cache is on: [PagedKVCache, RadixPrefixIndex, device pools] -- the
    # index's pages (and their contents) must outlive any single stream
    # for cross-request KV reuse to exist
    _shared_state: Optional[list] = field(default=None, repr=False)

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, t, c, pos: self.model.decode_step(p, t, c, pos),
            donate_argnums=(2,))   # KV cache updated in place
        # how many times the chunked-prefill function was *traced* (not
        # called): the trace-count test asserts it stays at 1 no matter
        # how many prompt lengths stream through
        self.prefill_trace_count = 0
        # prefill chunk *launches* (calls, not traces): prefix-cache hits
        # skip the matched prefix's launches entirely, asserted in tests
        self.prefill_launches = 0

    # ------------------------------------------------------------------
    def prefill(self, tokens: jax.Array):
        """tokens: (B, S_prompt).  Returns (cache, last_logits)."""
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.serve.max_seq_len)

        # scan over prompt positions (jit'd once)
        def scan_fn(params, tokens, cache):
            def step(c, t):
                lg, c = self.model.decode_step(params, tokens[:, t], c, t)
                return c, lg
            return jax.lax.scan(step, cache, jnp.arange(s))

        cache, all_logits = jax.jit(scan_fn)(self.params, tokens, cache)
        return cache, all_logits[-1]

    def generate(self, tokens: jax.Array, n_new: int,
                 key: Optional[jax.Array] = None):
        """Greedy/top-k generation.  Returns (B, n_new) tokens."""
        key = key if key is not None else jax.random.PRNGKey(self.serve.seed)
        b, s = tokens.shape
        cache, logits = self.prefill(tokens)
        out = []
        tok = sample_token(logits, key, temperature=self.serve.temperature,
                           top_k=self.serve.top_k)
        out.append(tok)
        for i in range(1, n_new):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok, cache, s + i - 1)
            tok = sample_token(logits, sub,
                               temperature=self.serve.temperature,
                               top_k=self.serve.top_k)
            out.append(tok)
        return jnp.stack(out, axis=1)

    # ------------------------------------------------------------------
    # paged KV + continuous batching
    # ------------------------------------------------------------------
    def _paged_impl(self) -> str:
        if self.serve.paged_impl == "auto":
            return default_paged_impl()
        return self.serve.paged_impl

    def _paged_fns(self):
        """Jitted paged fns keyed on the resolved impl so a serve-config
        change after first use is honoured: (scan prefill, chunked
        prefill, fused decode step).  The scan prefill retraces once per
        distinct prompt length (that is why it is the legacy path); the
        chunked prefill traces exactly once -- chunk shape, page-table
        width and position offsets are all runtime values."""
        impl = self._paged_impl()
        if (impl == "paged" and jax.default_backend() == "tpu"
                and self.serve.page_size % 128):
            raise ValueError(
                f"page_size={self.serve.page_size} must be a multiple of "
                "128 (TPU lane width) for the compiled Pallas paged "
                "kernel; pick a 128-multiple or paged_impl="
                "'paged_reference'")
        if impl not in self._paged_fn_cache:
            model = self.model
            engine = self

            def dec(params, tok, pools, table, pos):
                return model.decode_step_paged(params, tok, pools, table,
                                               pos, impl=impl)

            def pre_scan(params, prompt, pools, table_row, pos0):
                # pos0: (1,) int32 runtime offset -- a prefix-cache hit
                # scans only the uncached prompt tail from matched_len
                s = prompt.shape[1]

                def step(c, t):
                    lg, c = model.decode_step_paged(
                        params, prompt[:, t], c, table_row,
                        pos0 + t.astype(jnp.int32), impl=impl)
                    return c, lg

                pools, lgs = jax.lax.scan(step, pools, jnp.arange(s))
                return pools, lgs[-1]

            def pre_chunk(params, chunk, pools, table_row, pos_start,
                          n_valid):
                engine.prefill_trace_count += 1    # host-side, trace-time
                logits, pools = model.prefill_chunk_paged(
                    params, chunk, pools, table_row, pos_start, n_valid,
                    impl=impl)
                # the chunk's last *valid* row: only meaningful logits --
                # padding rows attended through the scratch page
                last = jnp.take_along_axis(
                    logits, jnp.maximum(n_valid - 1, 0)[:, None, None],
                    axis=1)[:, 0]
                return pools, last

            self._paged_fn_cache[impl] = (
                jax.jit(pre_scan, donate_argnums=(2,)),
                jax.jit(pre_chunk, donate_argnums=(2,)),
                jax.jit(dec, donate_argnums=(2,)))
        return self._paged_fn_cache[impl]

    def generate_stream(self, requests: Iterable[Request],
                        key: Optional[jax.Array] = None):
        """Continuous-batching generation over the paged KV cache.

        ``requests``: scheduler.Request objects (any number -- they queue).
        Yields StreamEvent(request_id, token, index, finished) as tokens
        are produced.  Each step the scheduler retires finished sequences
        (reclaiming their pages), admits waiting requests into freed
        slots, spends up to ``prefill_token_budget`` prompt tokens on
        chunked prefill of PREFILLING slots, then runs one fused decode
        step for every RUNNING slot -- decode tokens keep streaming while
        long prompts prefill.  Idle and mid-prefill slots write to the
        scratch page and are ignored.
        """
        serve = self.serve
        if serve.prefix_cache:
            # cross-request KV reuse: cache manager, radix index and the
            # device pools all persist across generate_stream calls
            if self._shared_state is None:
                mgr = PagedKVCache(serve.pool_pages(), serve.page_size,
                                   serve.max_batch, serve.max_pages_per_seq)
                prefix = RadixPrefixIndex(
                    mgr, serve.page_size, serve.prefix_cache_pages)
                self._shared_state = [mgr, prefix, None]
            mgr, prefix = self._shared_state[0], self._shared_state[1]
        else:
            mgr = PagedKVCache(serve.pool_pages(), serve.page_size,
                               serve.max_batch, serve.max_pages_per_seq)
            prefix = None
        sched = ContinuousBatchScheduler(
            mgr, serve.max_batch, admission=serve.admission,
            watermark_pages=serve.watermark, prefix_cache=prefix)
        pressure = PressureManager(self.cfg, serve, mgr, sched,
                                   prefix_cache=prefix)
        # observability: benchmarks/tests read peak page usage, retire
        # counts and preemption stats off the live objects after (or
        # during) the stream
        self.last_cache, self.last_scheduler = mgr, sched
        self.last_pressure, self.last_prefix = pressure, prefix
        # submit (and validate) eagerly, at the call site: the decode loop
        # is a generator and would otherwise defer errors to first next()
        for r in requests:
            sched.submit(r)
        return self._stream(mgr, sched, pressure, key)

    def _first_token(self, req, slot, last_logits, next_tok, key):
        """Sample a freshly-prefilled sequence's first token and flip the
        request into the decoding state."""
        req.state = RUNNING
        tok = int(sample_token(
            last_logits, key, temperature=self.serve.temperature,
            top_k=self.serve.top_k)[0])
        req.generated.append(tok)
        next_tok[slot] = tok
        return StreamEvent(req.id, tok, 0, req.done)

    @staticmethod
    def _apply_cow(mgr: PagedKVCache, pools):
        """Replay pending copy-on-write page moves on the device pools:
        the host manager already rewired the page table, the contents
        must follow before the next launch reads or writes the copy."""
        if not mgr.cow_pending:
            return pools
        pairs, mgr.cow_pending = mgr.cow_pending, []
        return copy_pages(pools, [s for s, _ in pairs],
                          [d for _, d in pairs])

    def _grow(self, mgr: PagedKVCache, pressure: PressureManager, pools,
              slot: int, n: int):
        """``mgr.append(slot, n)`` with page-pressure relief: on
        OutOfPages, reclaim prefix-cache leaves or evict the newest-
        admitted other sequence (swap or recompute) and retry.
        Terminates because submit-time validation guarantees any single
        request fits the pool alone.  Returns the (possibly replaced)
        pools with any copy-on-write page copies applied."""
        while True:
            try:
                mgr.append(slot, n)
                return self._apply_cow(mgr, pools)
            except OutOfPages:
                pressure.relieve(pools, protect=slot)

    @staticmethod
    def _prefill_groups(jobs, width: int):
        """Pack this step's prefill jobs into batched launches: first-fit
        into the earliest group that has room and no job for the same
        slot yet (a slot's chunk k+1 must launch after its chunk k; the
        first-fit order preserves that).  Distinct sequences' chunks ride
        one ``prefill_chunk_paged`` call instead of one launch each."""
        groups: list = []
        for job in jobs:
            slot = job[0]
            for g in groups:
                if len(g) < width and all(j[0] != slot for j in g):
                    g.append(job)
                    break
            else:
                groups.append([job])
        return groups

    def _resume_decode(self, req, slot, next_tok) -> None:
        """Flip a resumed sequence whose prefill state is fully restored
        back into decode: its next input token was already sampled before
        the preemption, so nothing is emitted here."""
        req.state = RUNNING
        next_tok[slot] = req.generated[-1]

    def _stream(self, mgr: PagedKVCache, sched: ContinuousBatchScheduler,
                pressure: PressureManager, key: Optional[jax.Array]):
        serve = self.serve
        ps = mgr.page_size
        npages = mgr.num_pages
        prefix = sched.prefix_cache
        persist = self._shared_state if serve.prefix_cache else None
        if persist is not None and persist[2] is not None:
            pools = persist[2]          # cached pages carry live KV
        else:
            pools = self.model.init_paged_cache(npages, ps)
        pre_scan, pre_chunk, decode = self._paged_fns()
        key = key if key is not None else jax.random.PRNGKey(serve.seed)
        next_tok = np.zeros((serve.max_batch,), np.int32)
        chunk = serve.prefill_chunk_tokens
        budget = serve.prefill_budget_tokens

        try:
            while sched.has_work:
                sched.retire()
                admitted = sched.admit()
                # RESUMING path: swap-preempted requests re-admitted by the
                # scheduler get their stashed KV copied back into the pages
                # admission just materialised (their shared prefix was
                # re-shared from the index); a sequence that was decoding
                # when evicted rejoins the decode batch directly (its next
                # input token was sampled before the preemption).  A stash
                # whose resume was downgraded to recompute is dropped.
                for slot, req in admitted:
                    if pressure.holds(req.id):
                        if req.resume_kind == "swap":
                            pools = pressure.restore(pools, slot, req)
                        else:
                            pressure.drop(req.id)
                    if req.state == RUNNING:
                        next_tok[slot] = req.generated[-1]
                if not admitted and not sched.running():
                    if not sched.waiting and not sched.resuming:
                        break               # everything retired
                    # submit-time validation guarantees the head of either
                    # queue fits an empty pool (the watermark is waived when
                    # no slot is occupied); kept as a cheap tripwire
                    req = (sched.resuming or sched.waiting)[0]
                    raise RuntimeError(
                        f"pool too small for request {req.id}: needs "
                        f"{-(-req.target_len // ps)} pages, pool has "
                        f"{npages - 1}")
                if serve.debug_invariants:
                    mgr.check_invariants(
                        extern_refs=prefix.page_refs() if prefix else None)

                # ---- prefill phase -------------------------------------------
                if serve.prefill_mode == "scan":
                    # legacy: the whole uncached (re)prefill tail at once,
                    # one token per scan step, retraced per length
                    # (equivalence oracle); a prefix-cache hit starts the
                    # scan at matched_len over the shared pages
                    for slot, req in admitted:
                        if sched.slots[slot] is not req \
                                or req.state != PREFILLING:
                            continue        # preempted again, or swap-resumed
                        start = req.prefilled
                        toks = req.prefill_tokens[start:]
                        pools = self._grow(mgr, pressure, pools, slot,
                                           len(toks))
                        pools, last_logits = pre_scan(
                            self.params, jnp.asarray(toks[None]), pools,
                            jnp.asarray(mgr.device_row(slot)),
                            jnp.full((1,), start, jnp.int32))
                        req.prefilled = start + len(toks)
                        if req.generated:
                            self._resume_decode(req, slot, next_tok)
                        else:
                            key, sub = jax.random.split(key)
                            yield self._first_token(req, slot, last_logits,
                                                    next_tok, sub)
                else:
                    # chunked: fixed-size chunks through the full forward,
                    # budgeted per step so decode slots keep producing; jobs
                    # for distinct sequences batch into one launch, padded to
                    # the next power-of-two row count (a lone prefilling
                    # prompt stays a 1-row launch; traces stay bounded by
                    # log2(max_batch)+1 widths, never by prompt length)
                    width = serve.max_batch
                    for group in self._prefill_groups(
                            sched.prefill_schedule(budget, chunk), width):
                        live = []
                        for slot, req, start, n in group:
                            if sched.slots[slot] is not req \
                                    or req.state != PREFILLING:
                                continue    # victim of an earlier _grow
                            pools = self._grow(mgr, pressure, pools, slot, n)
                            live.append((slot, req, start, n))
                        # _grow may have evicted an earlier group member
                        live = [(s, r, st, n) for s, r, st, n in live
                                if sched.slots[s] is r]
                        if not live:
                            continue
                        bw = 1
                        while bw < len(live):
                            bw *= 2
                        bw = min(bw, width)
                        buf = np.zeros((bw, chunk), np.int32)
                        table = np.full((bw, mgr.max_pages_per_seq),
                                        mgr.SCRATCH, np.int32)
                        pos0 = np.zeros((bw,), np.int32)
                        nval = np.zeros((bw,), np.int32)
                        for i, (slot, req, start, n) in enumerate(live):
                            buf[i, :n] = req.prefill_tokens[start:start + n]
                            table[i] = mgr.table[slot]
                            pos0[i] = start
                            nval[i] = n
                        self.prefill_launches += 1
                        pools, last_logits = pre_chunk(
                            self.params, jnp.asarray(buf), pools,
                            jnp.asarray(table), jnp.asarray(pos0),
                            jnp.asarray(nval))
                        for i, (slot, req, start, n) in enumerate(live):
                            req.prefilled = start + n
                            if not req.prefill_done:
                                continue
                            if req.generated:   # recompute-resume finished
                                self._resume_decode(req, slot, next_tok)
                            else:
                                key, sub = jax.random.split(key)
                                yield self._first_token(
                                    req, slot, last_logits[i:i + 1],
                                    next_tok, sub)

                # ---- decode phase --------------------------------------------
                cand = [(s, r) for s, r in sched.decoding() if not r.done]
                # materialise the page (maybe a fresh one) every running
                # sequence's next token will be written to -- evicting other
                # sequences under pressure -- THEN snapshot the table for the
                # device step.
                for slot, req in cand:
                    if sched.slots[slot] is not req:
                        continue            # evicted by an earlier _grow
                    pools = self._grow(mgr, pressure, pools, slot, 1)
                running = [(s, r) for s, r in cand if sched.slots[s] is r]
                if serve.debug_invariants:
                    mgr.check_invariants(
                        extern_refs=prefix.page_refs() if prefix else None)
                if not running:
                    continue
                pos_np = np.zeros((serve.max_batch,), np.int32)
                for slot, _ in running:
                    pos_np[slot] = mgr.seq_len(slot) - 1
                table = mgr.device_table()
                for slot, _ in sched.prefilling():
                    # mid-prefill slots sit out the decode step: scratch-page
                    # table row + pos 0, like idle slots (their real pages
                    # must not see the decode step's writes)
                    table[slot, :] = mgr.SCRATCH
                logits, pools = decode(
                    self.params, jnp.asarray(next_tok), pools,
                    jnp.asarray(table), jnp.asarray(pos_np))
                key, sub = jax.random.split(key)
                toks = np.asarray(sample_token(
                    logits, sub, temperature=serve.temperature,
                    top_k=serve.top_k))
                for slot, req in running:
                    tok = int(toks[slot])
                    req.generated.append(tok)
                    next_tok[slot] = tok
                    yield StreamEvent(req.id, tok, len(req.generated) - 1,
                                      req.done)
        finally:
            # A stream can end early: the caller abandons the generator
            # (GeneratorExit) or an error escapes.  With persistent
            # prefix-cache state the shared manager/pools outlive this
            # call, so reconcile: this stream's live slots are freed
            # (their requests are lost with the call, shared pages just
            # drop one reference), un-replayed COW debts die with them,
            # and the persisted pools reference is refreshed -- `pools`
            # is always the latest post-launch (undonated) object.
            if persist is not None:
                mgr.cow_pending.clear()
                for slot in range(sched.max_slots):
                    if sched.slots[slot] is not None \
                            and mgr.is_active(slot):
                        mgr.free(slot)
                        sched.slots[slot] = None
                persist[2] = pools

    def throughput_tokens_per_s(self, batch: int, prompt_len: int,
                                n_new: int = 8) -> float:
        """Measured decode throughput (benchmark helper)."""
        import time
        tokens = jnp.zeros((batch, prompt_len), jnp.int32)
        cache, logits = self.prefill(tokens)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        # warmup + timed loop
        logits, cache = self._decode(self.params, tok, cache, prompt_len)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for i in range(n_new):
            logits, cache = self._decode(self.params, tok, cache,
                                         prompt_len + 1 + i)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return batch * n_new / dt
