"""Speculative decoding: prompt-lookup drafting + multi-token verify.

The decode step emits one token per request per launch, so TPOT is
floored by per-step overhead.  Speculation breaks that floor without a
second model: a *drafter* guesses the next K tokens of each running
request from its own text (prompt-lookup n-gram matching, the
"prompt lookup decoding" trick -- highly effective on extraction,
summarisation and code where the output quotes the input), the engine
appends the guesses to the paged KV and scores all K+1 positions in ONE
chunked paged-prefill launch (FlashInfer treats verify attention as a
first-class kernel shape; our ``paged_prefill_fwd`` with dynamic
``pos_start``/``n_valid`` already is that shape), and an acceptance rule
keeps the longest valid prefix:

    drafter      d1 .. dK          = continuation after the last match
    verify row   [t0, d1 .. dK]    -> logits L0 .. LK  (one launch)
    accept       greedy: keep di while di == argmax(L[i-1]);
                 sampled: keep di with prob p(di), else residual-sample
    emit         accepted drafts + one correction/bonus token
    rollback     PagedKVCache.truncate() drops the rejected rows' KV

Greedy streams are bit-identical to the plain decode path: the verify
logits come from the same kernels the chunked-prefill == scan == decode
equivalence oracle already pins down, and the emitted token at every
position is the target argmax whether or not the draft matched.  At
``temperature > 0`` the accept/residual coins compose with the engine's
counter-based RNG -- the keys for generated token index ``n`` derive
only from ``fold_in(PRNGKey(seed), n)`` -- so sampled acceptance is
replayable and invariant to batch composition, and K=0 degenerates
bit-for-bit into the normal sampling path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.faults import LogitError


class Drafter:
    """Protocol for speculation drafters: propose continuation tokens
    for a running request, learn from verification feedback, drop
    per-request state when the request leaves the engine."""

    def propose(self, req) -> List[int]:
        """Up to ``max_tokens`` guessed continuations of
        ``req.prompt + req.generated`` (may be empty)."""
        raise NotImplementedError

    def observe(self, request_id: int, proposed: int,
                accepted: int) -> None:
        """Verification feedback for one step: ``accepted`` of
        ``proposed`` drafts survived."""

    def forget(self, request_id: int) -> None:
        """Drop any state for a retired/aborted/failed request."""

    def reset(self) -> None:
        """Drop all per-request state."""


class PromptLookupDrafter(Drafter):
    """N-gram prompt-lookup drafter: no second model, no extra launch.

    Each request's ``prompt + generated`` text is indexed incrementally
    (suffix n-grams of length ``ngram_min..ngram_max`` -> their two most
    recent end positions).  To draft, the current suffix is matched
    longest-n-gram-first and the tokens that followed the previous
    occurrence are proposed verbatim.  A per-request accept-rate EMA
    adapts K: requests whose text never repeats stop paying for failed
    speculation (K shrinks toward 1), repetitive requests draft the full
    ``max_tokens``."""

    def __init__(self, *, max_tokens: int, ngram_max: int = 3,
                 ngram_min: int = 1, ema_alpha: float = 0.5):
        if max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {max_tokens}")
        if not 1 <= ngram_min <= ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"[{ngram_min}, {ngram_max}]")
        if not 0.0 <= ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha must be in [0, 1], got {ema_alpha}")
        self.max_tokens = max_tokens
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min
        self.ema_alpha = ema_alpha
        # request id -> {ngram tuple: (latest end pos, previous end pos)}
        self._index: Dict[int, Dict[Tuple[int, ...], Tuple[int, int]]] = {}
        self._indexed: Dict[int, int] = {}      # tokens indexed so far
        self._ema: Dict[int, float] = {}        # accept-rate estimate

    def budget(self, request_id: int) -> int:
        """Adaptive K: scale ``max_tokens`` by the request's accept-rate
        EMA (optimistic full K before any feedback; never below 1 --
        a 1-token draft is how a cold estimate recovers)."""
        if self.ema_alpha == 0.0:
            return self.max_tokens
        ema = self._ema.get(request_id)
        if ema is None:
            return self.max_tokens
        return max(1, int(round(ema * self.max_tokens)))

    def propose(self, req) -> List[int]:
        ctx = [int(t) for t in req.prompt] + [int(t) for t in req.generated]
        rid = req.id
        idx = self._index.setdefault(rid, {})
        length = len(ctx)
        # incremental indexing: only n-grams ending past the last call's
        # high-water mark are new (generation is append-only; KV rollback
        # never shrinks ``generated``)
        for end in range(self._indexed.get(rid, 0) + 1, length + 1):
            for n in range(self.ngram_min, min(self.ngram_max, end) + 1):
                key = tuple(ctx[end - n:end])
                prev = idx.get(key)
                idx[key] = (end, prev[0] if prev is not None else -1)
        self._indexed[rid] = length
        k = self.budget(rid)
        # longest suffix match first; the suffix's own occurrence ends at
        # ``length`` (empty continuation), so the two-deep index lets the
        # previous occurrence supply the draft
        for n in range(min(self.ngram_max, length), self.ngram_min - 1, -1):
            hit = idx.get(tuple(ctx[length - n:length]))
            if hit is None:
                continue
            for end in hit:
                if 0 <= end < length:
                    return ctx[end:end + min(k, length - end)]
        return []

    def observe(self, request_id: int, proposed: int,
                accepted: int) -> None:
        if proposed <= 0 or self.ema_alpha == 0.0:
            return
        rate = accepted / proposed
        prev = self._ema.get(request_id)
        self._ema[request_id] = rate if prev is None else (
            self.ema_alpha * rate + (1.0 - self.ema_alpha) * prev)

    def forget(self, request_id: int) -> None:
        self._index.pop(request_id, None)
        self._indexed.pop(request_id, None)
        self._ema.pop(request_id, None)

    def reset(self) -> None:
        self._index.clear()
        self._indexed.clear()
        self._ema.clear()


# ---------------------------------------------------------------------------
# acceptance
# ---------------------------------------------------------------------------
# Both verifiers consume the logits of one request's verify row
# [t0, d1 .. dK]: row i is the target distribution for generated token
# index n0+i (row 0 is exactly what the plain decode step would have
# produced).  They return (tokens, accepted): ``tokens`` is everything
# the request emits this step -- accepted drafts plus one correction or
# bonus token -- and ``accepted`` counts surviving drafts (drives the
# drafter's EMA and the accept-rate metrics).  ``row_ok`` is the
# engine's per-row finite-logits guard; a row is only checked when its
# logits are actually consumed, so K=0 behaves exactly like the plain
# path.


def _guard_row(row_ok, i: int, request_id: int, token_index: int) -> None:
    if row_ok is not None and not bool(row_ok[i]):
        raise LogitError(
            f"request {request_id}: non-finite logits at token "
            f"{token_index}", request_id=request_id)


def verify_greedy(drafts: Sequence[int], argmax_rows, *,
                  stop_ids: Sequence[int] = (), budget: int,
                  row_ok=None, request_id: int = -1, n0: int = 0
                  ) -> Tuple[List[int], int]:
    """Greedy acceptance: the emitted token at every position IS the
    target argmax, so the stream is bit-identical to plain decode; a
    draft merely decides whether the next row's logits were conditioned
    on the right token and may be consumed.  Acceptance stops at a stop
    token or the request's remaining-token ``budget``; a full match
    earns the bonus token from the last row."""
    out: List[int] = []
    accepted = 0
    stop = frozenset(int(s) for s in stop_ids)
    for i, d in enumerate(drafts):
        _guard_row(row_ok, i, request_id, n0 + i)
        t = int(argmax_rows[i])
        out.append(t)
        if t != int(d):
            return out, accepted        # correction token; rest rejected
        accepted += 1
        if t in stop or len(out) >= budget:
            return out, accepted
    _guard_row(row_ok, len(drafts), request_id, n0 + len(drafts))
    out.append(int(argmax_rows[len(drafts)]))
    return out, accepted


def _processed_logits(row, temperature: float, top_k: int):
    """Temperature/top-k processing identical to ``core.sample_token``:
    the acceptance coin must measure exactly the distribution the plain
    sampler would have drawn from."""
    lf = jnp.asarray(row).astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 1:
        k = min(top_k, lf.shape[-1])
        vals, _ = jax.lax.top_k(lf, k)
        lf = jnp.where(lf < vals[..., -1:], -1e30, lf)
    return lf


def verify_residual(drafts: Sequence[int], logits_rows, *, seed: int,
                    n0: int, temperature: float, top_k: int = 0,
                    stop_ids: Sequence[int] = (), budget: int,
                    row_ok=None, request_id: int = -1
                    ) -> Tuple[List[int], int]:
    """Leftover/residual rejection sampling against a deterministic
    drafter (q is a point mass, so the accept probability for draft d
    is simply p(d) under the processed target distribution; the
    rejection residual is p with d removed, renormalised -- the emitted
    marginal at every position is exactly p).

    RNG discipline: token index n uses sub-keys of
    ``fold_in(PRNGKey(seed), n)`` -- ``fold_in(key_n, 1)`` for the
    accept coin, ``fold_in(key_n, 2)`` for the residual draw -- and the
    bonus/K=0 token uses ``key_n`` through ``core.sample_token``
    itself, so a draft-less step is bit-identical to plain decode and
    every draw replays from (seed, token index) alone, invariant to
    batch composition and speculation history."""
    from repro.serving.core import sample_token   # circular at import time
    out: List[int] = []
    accepted = 0
    stop = frozenset(int(s) for s in stop_ids)
    for i, d in enumerate(drafts):
        _guard_row(row_ok, i, request_id, n0 + i)
        d = int(d)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), n0 + i)
        lf = _processed_logits(logits_rows[i], temperature, top_k)
        p_d = float(jax.nn.softmax(lf)[d])
        u = float(jax.random.uniform(jax.random.fold_in(key, 1)))
        if u < p_d:
            out.append(d)
            accepted += 1
            if d in stop or len(out) >= budget:
                return out, accepted
            continue
        resid = lf.at[d].set(-1e30)     # p with d zeroed, renormalised
        tok = int(jax.random.categorical(jax.random.fold_in(key, 2),
                                         resid))
        out.append(tok)
        return out, accepted
    i = len(drafts)
    _guard_row(row_ok, i, request_id, n0 + i)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), n0 + i)
    tok = sample_token(jnp.atleast_2d(jnp.asarray(logits_rows[i])), key,
                       temperature=temperature, top_k=top_k)
    out.append(int(np.asarray(tok).ravel()[0]))
    return out, accepted
