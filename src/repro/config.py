"""Configuration system for the repro framework.

Three layers of config:
  * ModelConfig    -- architecture definition (one per --arch).
  * ParallelConfig -- mesh + sharding + paper-technique toggles.
  * ShapeConfig    -- workload shape (one per assigned input-shape set).

Configs are plain frozen dataclasses so they hash and can be closed over by
jit.  ``repro.configs`` registers one ModelConfig per assigned architecture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Sequence

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Block kinds understood by models/lm.py.  A model is a (possibly repeating)
# pattern of these:
#   attn        -- pre-norm GQA attention + MLP (dense transformer layer)
#   attn_local  -- same but sliding-window attention
#   moe         -- attention + mixture-of-experts FFN
#   mlstm       -- xLSTM matrix-LSTM block (no separate FFN)
#   slstm       -- xLSTM scalar-LSTM block
#   hymba       -- parallel attention + mamba heads sharing one residual
#   hymba_local -- hymba with sliding-window attention heads
BLOCK_KINDS = (
    "attn", "attn_local", "moe", "mlstm", "slstm", "hymba", "hymba_local",
)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Per-layer block pattern.  ``block_pattern`` is tiled/truncated to
    # ``num_layers``; default is all-"attn".
    block_pattern: tuple = ("attn",)

    # --- attention options -------------------------------------------------
    attention_impl: str = "reference"   # reference | pallas (TPU only)
    causal: bool = True
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    window_size: Optional[int] = None   # for *_local blocks
    rope_type: str = "rope"             # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: tuple = (16, 24, 24)  # M-RoPE split of head_dim//2

    # --- norms / mlp --------------------------------------------------------
    norm_type: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    mlp_type: str = "swiglu"            # swiglu | geglu | gelu
    post_norm: bool = False             # gemma2-style post-block norms

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_dff: int = 0                    # per-expert hidden (0 -> use d_ff)

    # --- SSM / recurrent ----------------------------------------------------
    ssm_state_size: int = 16            # mamba state (hymba)
    mlstm_proj_factor: float = 2.0      # xLSTM up-projection factor
    conv_kernel: int = 4                # mamba local conv width

    # --- encoder-decoder (whisper) ------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500             # audio frames after conv stub
    modality: str = "text"              # text | audio_stub | vision_stub

    # --- embeddings / dtypes -------------------------------------------------
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    embed_scale: bool = False           # gemma-style sqrt(d) embedding scale

    # ------------------------------------------------------------------
    def blocks(self) -> tuple:
        """The per-layer block-kind tuple, length == num_layers."""
        pat = self.block_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def expert_dff(self) -> int:
        return self.moe_dff or self.d_ff

    def param_count(self) -> int:
        """Total parameter count (approximate analytic model, matches the
        constructed pytree to within embedding-tying details)."""
        from repro.analysis.flops import param_count
        return param_count(self)

    def active_param_count(self) -> int:
        from repro.analysis.flops import param_count
        return param_count(self, active_only=True)

    def is_subquadratic(self) -> bool:
        """True if no block is full (global) quadratic attention, i.e. the
        arch is eligible for the long_500k shape."""
        quad = {"attn", "moe"}
        if self.is_encoder_decoder:
            return False
        return not any(b in quad for b in self.blocks())


# ---------------------------------------------------------------------------
# Parallel / distribution configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    # Mesh shape.  pods * data * model == number of devices.
    pods: int = 1
    data: int = 1
    model: int = 1

    # Attention distribution mode on the `model` axis:
    #   context -- Q sharded along seq, GQA KV gathered (train/prefill);
    #              decode shards the KV cache along cache-seq + LSE merge.
    #   replicated -- attention unsharded (tiny models / smoke tests).
    attn_mode: str = "context"

    # --- paper T3: tiling-AllReduce ----------------------------------------
    tiled_allreduce: bool = False
    ar_chunks: int = 4
    first_chunk_frac: float = 0.5       # paper: make the first block smaller

    # --- memory/perf knobs ---------------------------------------------------
    remat: str = "selective"            # none | full | selective
    scan_layers: bool = True            # lax.scan over homogeneous blocks
    grad_compression: str = "none"      # none | int8_ef
    microbatches: int = 1               # gradient accumulation steps
    seq_shard_activations: bool = True  # Megatron-SP activation layout

    # --- paper T4: CPU-GPU cooperative offload -------------------------------
    offload_kv: bool = False
    host_memory_gb: float = 512.0
    device_memory_gb: float = 16.0      # v5e HBM
    pcie_gbps: float = 32.0             # host<->device bidirectional

    # pipeline parallelism over the pod axis (optional feature)
    pipeline_stages: int = 1

    @property
    def dp(self) -> int:
        return self.pods * self.data

    def mesh_shape(self):
        if self.pods > 1:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)

    def mesh_axes(self):
        if self.pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")

    def dp_axes(self):
        return ("pod", "data") if self.pods > 1 else ("data",)


# ---------------------------------------------------------------------------
# Workload shapes (assigned input-shape set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    gen_tokens: int = 1            # decode steps per serve_step call


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Training / serving runtime config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq_len: int = 4096
    # DEPRECATED engine-global sampling knobs: requests carry their own
    # frozen SamplingParams (serving/scheduler.py) with a counter-based
    # per-request RNG stream.  These fields survive only as the defaults
    # for requests submitted without params (the EngineCore warns once
    # per core when they were changed from these values) and for the
    # dense ServeEngine.generate path.
    temperature: float = 1.0
    top_k: int = 0                 # 0 = no truncation (1 = greedy)
    seed: int = 0

    # --- paged KV + continuous batching (ServeEngine.generate_stream) ---
    # page_size doubles as the paged decode kernel's block_kv: one page
    # table entry == one kernel grid step.  Must be a multiple of 128
    # (TPU lane width) on real hardware.
    page_size: int = 128
    # Physical pages in the shared pool (page 0 is scratch).  0 = auto:
    # enough for max_batch sequences of max_seq_len, i.e. a dense cache's
    # worth -- set it lower to actually oversubscribe.
    num_pages: int = 0
    # paged decode impl: auto | paged | paged_interpret | paged_reference
    # (auto = Pallas kernel on TPU, jittable gather-reference elsewhere).
    paged_impl: str = "auto"

    # --- chunked prefill (Sarathi-style prefill/decode interleaving) ---
    # Prompt tokens per prefill kernel launch (the tiled-forward chunk).
    # 0 = auto: 4 pages.  Jit traces are keyed by this, never by prompt
    # length.
    prefill_chunk: int = 0
    # Prefill tokens per engine step before the fused decode step for
    # all running slots; 0 = auto (one chunk).  A soft cap, rounded up
    # to whole chunks (worst case budget + prefill_chunk - 1 tokens).
    # Smaller = lower decode latency under long-prompt arrival, larger
    # = faster TTFT.
    prefill_token_budget: int = 0
    # "chunked" = tiled full-forward prefill (the fast path); "scan" =
    # legacy token-at-a-time teacher forcing, kept as the equivalence
    # oracle.
    prefill_mode: str = "chunked"

    # --- page pressure: optimistic admission + preemption ---------------
    # "optimistic" admits a request when its *prompt* fits beside a small
    # watermark reserve -- decode growth is backed by preemption instead
    # of a reservation.  "reserved" is the PR 1 worst-case-reservation
    # baseline (admission gated on prompt + max_new_tokens; never
    # preempts), kept for the over-subscription bench comparison.
    admission: str = "optimistic"
    # Free pages held back at admission so steady decode growth rarely
    # trips a preemption the very next step.  0 = auto (half the slots).
    watermark_pages: int = 0
    # Victim handling under OutOfPages: "swap" copies the victim's KV
    # pages to the host page pool and restores them on resume (exact);
    # "recompute" re-prefills prompt + generated tokens through chunked
    # prefill; "auto" picks per victim via the PCIe/FLOPs cost model
    # (core/offload.py:preempt_cost_model).
    preempt_policy: str = "auto"
    # Host page pool capacity (in pages) for swapped-out KV; 0 =
    # unbounded.  A full host pool downgrades swap victims to recompute.
    host_pool_pages: int = 0
    # Run PagedKVCache.check_invariants every engine step (debug/tests).
    debug_invariants: bool = False

    # --- prefix cache: cross-request KV reuse ---------------------------
    # Radix-tree prefix cache (serving/prefix_cache.py): retiring
    # sequences publish their page-aligned prefix blocks; a new request
    # shares the longest matching cached page run copy-on-write and
    # skips recomputing it (chunked prefill starts at matched_len).  The
    # paged state (page manager, index, device pools) then persists
    # across generate_stream calls on the same engine.  Greedy outputs
    # stay bit-identical to a cold run -- shared pages hold exactly the
    # KV the prefix would recompute.
    prefix_cache: bool = False
    # Cap on pages the index may keep resident (LRU leaf eviction);
    # 0 = unbounded -- the pool itself is the bound, with leaves
    # reclaimed whenever the free list runs low.
    prefix_cache_pages: int = 0

    # --- fault tolerance & graceful degradation (serving/faults.py) -----
    # Non-finite (NaN/Inf) logits: "fail" quarantines only the offending
    # request (terminal FAILED state + a structured error event, pages
    # freed, co-tenants untouched); "ignore" keeps the pre-guard
    # behaviour (argmax over a NaN row is garbage-but-defined).
    logit_guard: str = "fail"
    # Bound on the waiting queue (0 = unbounded, the legacy behaviour).
    # An over-offered engine then degrades by policy instead of queueing
    # without limit.
    max_waiting: int = 0
    # What a full waiting queue does to the next submit: "reject" raises
    # a structured RequestRejected at add_request; "shed_oldest" fails
    # the oldest waiting request (error event) and admits the newcomer.
    queue_policy: str = "reject"
    # Transient swap DMA failures (device<->host page copies) are
    # retried this many times with bounded exponential backoff before
    # the victim is downgraded to recompute via the preemption cost
    # path -- a swap fault never fails the request.
    swap_retries: int = 3
    # Base of the retry backoff (seconds); attempt k sleeps
    # min(base * 2**k, 0.1).  0 disables sleeping (tests).
    swap_retry_backoff_s: float = 0.0

    # --- telemetry (serving/metrics.py) ---------------------------------
    # Master switch for the engine telemetry subsystem: per-step phase
    # timings, per-request lifecycle spans (TTFT/TPOT/queue-delay
    # histograms) and the step flight recorder.  The registry itself
    # (counters backing ``stats()``) always runs -- it is a handful of
    # integer adds per step; this gates the clock reads.  All telemetry
    # is host-side only and can never change jit trace counts.
    metrics: bool = True
    # Ring-buffer depth of the step flight recorder: how many recent
    # step records survive for an ``EngineError``/quarantine postmortem
    # dump (and the Chrome trace_event export).
    flight_recorder_steps: int = 64

    # --- speculative decoding (serving/spec.py) -------------------------
    # "off" keeps the one-token-per-launch decode step byte-for-byte;
    # "lookup" drafts continuation tokens from each request's own
    # prompt+generated text (prompt-lookup n-gram matching, no second
    # model) and verifies all of them in one chunked paged-prefill
    # launch.  Greedy token streams are bit-identical either way.
    spec_mode: str = "off"
    # Max drafted tokens per request per step (the verify launch scores
    # spec_tokens + 1 positions).  Per-request adaptive K shrinks below
    # this from a running accept-rate EMA.
    spec_tokens: int = 4
    # Suffix n-gram lengths the prompt-lookup drafter matches, tried
    # longest-first.
    spec_ngram_max: int = 3
    spec_ngram_min: int = 1
    # EMA smoothing for the per-request accept-rate estimate driving
    # adaptive K; 0 disables adaptation (always draft spec_tokens).
    spec_ema_alpha: float = 0.5

    # --- tensor parallelism (sharding/tp.py) ----------------------------
    # Device count to shard attention + KV page pools over.  Factored as
    # gcd(tp, num_kv_heads) kv-head groups x within-page row sub-shards
    # (partial attention outputs merge exactly via the LSE combination),
    # so tp may exceed the KV head count.  1 = single-device engine.
    tp: int = 1
    # O-proj / down-proj partial-sum collectives: "tiled" overlaps the
    # AllReduce with per-chunk matmuls (paper §4.2 T3); "single" is the
    # monolithic baseline the serving benchmark compares against.
    tp_collectives: str = "tiled"
    tp_ar_chunks: int = 4
    tp_first_chunk_frac: float = 0.5

    @property
    def sampling_overridden(self) -> bool:
        """True when the deprecated engine-global sampling knobs were
        changed from their defaults -- the EngineCore warns (once) only
        when a params-less request actually inherits such a change."""
        return (self.temperature, self.top_k) != (1.0, 0)

    @property
    def watermark(self) -> int:
        return self.watermark_pages or max(1, self.max_batch // 2)

    @property
    def max_pages_per_seq(self) -> int:
        return -(-self.max_seq_len // self.page_size)

    @property
    def prefill_chunk_tokens(self) -> int:
        return self.prefill_chunk or 4 * self.page_size

    @property
    def prefill_budget_tokens(self) -> int:
        return max(self.prefill_token_budget or self.prefill_chunk_tokens, 1)

    def pool_pages(self) -> int:
        if self.num_pages:
            return self.num_pages
        return self.max_batch * self.max_pages_per_seq + 1


@dataclass(frozen=True)
class RunConfig:
    # default_factory everywhere: class-level default *instances* would be
    # shared across every RunConfig (harmless only while the configs stay
    # frozen -- don't rely on it).
    model: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    shape: ShapeConfig = field(default_factory=lambda: SHAPES["train_4k"])
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)


# ---------------------------------------------------------------------------
# Registry + CLI
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(name: str, fn: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = fn


def available_archs() -> Sequence[str]:
    _load_builtin_configs()
    return sorted(_REGISTRY)


def get_model_config(name: str) -> ModelConfig:
    _load_builtin_configs()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name]()


_LOADED = False


def _load_builtin_configs() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import repro.configs  # noqa: F401  (imports register all built-ins)


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """A tiny config of the same family for CPU smoke tests.

    Keeps the block pattern (truncated), GQA-ness, and every structural
    feature; shrinks widths/layers/vocab.
    """
    n_layers = min(cfg.num_layers, 2 if not cfg.is_encoder_decoder else 2)
    kv = min(cfg.num_kv_heads, 2)
    q_per_kv = max(1, cfg.num_heads // cfg.num_kv_heads)
    heads = kv * q_per_kv
    head_dim = 16
    updates = dict(
        num_layers=n_layers,
        d_model=heads * head_dim,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=4 * heads * head_dim if cfg.d_ff else 0,
        vocab_size=256,
        window_size=32 if cfg.window_size else None,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.num_experts:
        updates.update(num_experts=4,
                       num_experts_per_tok=min(2, cfg.num_experts_per_tok),
                       moe_dff=64)
    if cfg.is_encoder_decoder:
        updates.update(encoder_layers=2, encoder_seq=16)
    if cfg.mrope_sections and cfg.rope_type == "mrope":
        updates.update(mrope_sections=(2, 3, 3))
    return replace(cfg, **updates)


def describe(cfg: ModelConfig) -> str:
    n = cfg.param_count()
    return (f"{cfg.name}: {cfg.family} {cfg.num_layers}L d={cfg.d_model} "
            f"H={cfg.num_heads}/{cfg.num_kv_heads} ff={cfg.d_ff} "
            f"V={cfg.vocab_size} params={n/1e9:.2f}B")
