"""Tensor-parallel plan + context for the paged serving engine.

The paged engine shards attention and the per-layer KV page pools over a
2-D device mesh ``(axis_heads, axis_seq)``:

  * ``axis_heads`` (size ``g = gcd(tp, num_kv_heads)``) splits the KV
    heads into groups -- classic Megatron head parallelism; every shard
    of a group holds the group's full KV rows for its page slice.
  * ``axis_seq``  (size ``s = tp // g``) splits each KV *page* into
    ``s`` row sub-shards (the within-page token dimension).  Each
    sub-shard attends over its own rows only and the partial outputs
    merge exactly via the log-sum-exp combination of
    ``core/distributed_decode.py`` -- the same online-softmax
    decomposition the paper tiles within one NPU, promoted to the mesh.

The factoring means a 4-way mesh still works when the model has only 2
KV heads (the smoke configs): ``tp=4, Hkv=2 -> g=2, s=2``.  With
``s == 1`` the seq axis is size 1 and the LSE merge degenerates to the
identity -- pure head parallelism.

Model code discovers the active plan through a contextvar
(``current_tp()``); ``EngineCore._paged_fns`` enters ``tp_context`` at
trace time, so the same layer code serves the single-device and the
sharded engine.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from dataclasses import dataclass
from typing import Optional

from jax.sharding import Mesh

from repro.config import ModelConfig

AXIS_HEADS = "model"      # kv-head groups (the ISSUE's `model` axis)
AXIS_SEQ = "tp_seq"       # within-page row sub-shards


@dataclass(frozen=True)
class TPPlan:
    """Static tensor-parallel factoring (frozen: jit-cache key)."""
    g: int                       # kv-head groups over AXIS_HEADS
    s: int                       # page-row sub-shards over AXIS_SEQ
    collectives: str = "tiled"   # O-proj/down-proj allreduce: tiled|single
    ar_chunks: int = 4
    first_chunk_frac: float = 0.5

    @property
    def tp(self) -> int:
        return self.g * self.s

    @property
    def axes(self):
        """Mesh axis names, reduction order (heads, seq)."""
        return (AXIS_HEADS, AXIS_SEQ)

    @property
    def mesh_shape(self):
        return (self.g, self.s)


def plan_tp(cfg: ModelConfig, tp: int, page_size: int, *,
            collectives: str = "tiled", ar_chunks: int = 4,
            first_chunk_frac: float = 0.5) -> TPPlan:
    """Factor ``tp`` into (kv-head groups) x (page-row sub-shards) and
    validate the shapes divide.  Raises ValueError on impossible
    combinations rather than silently mis-sharding."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if collectives not in ("tiled", "single"):
        raise ValueError(f"tp_collectives must be 'tiled' or 'single', "
                         f"got {collectives!r}")
    g = math.gcd(tp, cfg.num_kv_heads)
    s = tp // g
    if cfg.num_heads % cfg.num_kv_heads:
        raise ValueError(
            f"GQA requires num_heads ({cfg.num_heads}) divisible by "
            f"num_kv_heads ({cfg.num_kv_heads})")
    hq_group = cfg.num_heads // g
    if hq_group % s:
        raise ValueError(
            f"tp={tp}: the {hq_group} query heads of each of the {g} "
            f"kv-head groups do not split over {s} page-row sub-shards "
            f"(O-proj is row-parallel over query-head slices)")
    if page_size % s:
        raise ValueError(
            f"tp={tp}: page_size={page_size} does not split into {s} "
            f"page-row sub-shards; pick a page size divisible by "
            f"tp // gcd(tp, num_kv_heads)")
    return TPPlan(g=g, s=s, collectives=collectives, ar_chunks=ar_chunks,
                  first_chunk_frac=first_chunk_frac)


@dataclass(frozen=True)
class TPContext:
    """An active plan bound to its device mesh."""
    mesh: Mesh
    plan: TPPlan


_TP: contextvars.ContextVar = contextvars.ContextVar("tp_context",
                                                     default=None)


@contextlib.contextmanager
def tp_context(mesh: Mesh, plan: TPPlan):
    """Activate tensor parallelism for model code traced inside.

    Entered by ``EngineCore._paged_fns`` around the paged forward
    functions; ``layers/attention.py`` and ``layers/mlp.py`` read it at
    trace time and switch to their shard_map TP bodies.
    """
    for ax, size in zip(plan.axes, plan.mesh_shape):
        if mesh.shape.get(ax) != size:
            raise ValueError(
                f"mesh axis {ax!r} has size {mesh.shape.get(ax)}, "
                f"plan needs {size} (mesh {dict(mesh.shape)})")
    token = _TP.set(TPContext(mesh=mesh, plan=plan))
    try:
        yield
    finally:
        _TP.reset(token)


def current_tp() -> Optional[TPContext]:
    return _TP.get()
