from repro.sharding.rules import (  # noqa: F401
    AxisRules, axis_rules, constrain, current_rules, param_sharding_tree,
    logical_to_spec,
)
