"""Logical-axis sharding rules (GSPMD guidance layer).

Model code annotates tensors with *logical* axis names
(``constrain(x, "batch", "seq", None)``); a context-scoped rule table maps
logical names to mesh axes.  Parameters carry logical axes in their
initializers and get NamedShardings from the same table, so one rule change
re-shards the whole model (the hillclimb lever).

Default rule table (see DESIGN.md §4.1):
    batch   -> (pod, data)      DP across pods and the data axis
    seq     -> model            Megatron-style sequence/context parallelism
    ff      -> model            column/row-parallel FFN
    expert  -> model            EP when num_experts % model == 0
    vocab   -> model
    kv_seq  -> model            decode: KV cache sharded along cache seq
    channels-> model            SSM channel sharding (mamba/mLSTM dv)
    heads   -> None             (context-parallel attention: heads local)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class AxisRules(dict):
    """Mapping from logical axis name -> mesh axis (str, tuple, or None)."""


def default_rules(multi_pod: bool = False) -> AxisRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return AxisRules({
        "batch": dp,
        "seq": "model",
        "ff": "model",
        "expert": "model",
        "vocab": "model",
        "kv_seq": "model",
        "channels": "model",
        "heads": None,
        "attn_row": "model",   # QKV/O weight input dim (row-parallel)
        "d_model": None,
        "stage": "pod",
        # paged tensor-parallel serving (sharding/tp.py): KV page pools
        # (Hkv, P, page_size, D) shard kv heads over the head-group axis
        # and within-page rows over the page-row axis
        "kv_heads": "model",
        "page_row": "tp_seq",
    })


_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "axis_rules", default=None)
_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "axis_mesh", default=None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules] = None,
               mesh: Optional[Mesh] = None):
    """Activate a rule table (and optionally a mesh) for model code."""
    if rules is None and mesh is not None:
        rules = default_rules(multi_pod="pod" in mesh.axis_names)
    t1 = _RULES.set(rules)
    t2 = _MESH.set(mesh)
    try:
        yield rules
    finally:
        _RULES.reset(t1)
        _MESH.reset(t2)


def current_rules() -> Optional[AxisRules]:
    return _RULES.get()


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Optional[AxisRules] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Translate logical axis names to a PartitionSpec under the rules.

    Drops mesh axes that do not exist (e.g. 'pod' on a single-pod mesh) and
    axes whose dimension would not divide -- divisibility is checked by the
    caller via ``constrain`` (which sees the array).
    """
    rules = rules or current_rules() or AxisRules()
    mesh = mesh or current_mesh()
    axes = []
    used: set = set()
    for name in logical:
        ax = rules.get(name) if name else None
        if ax is None:
            axes.append(None)
            continue
        if isinstance(ax, str):
            ax = (ax,)
        # drop axes missing from the mesh and axes already used by an
        # earlier dim (a tensor can map each mesh axis only once; first
        # occurrence wins, e.g. seq beats ff for activations)
        ax = tuple(a for a in ax
                   if (mesh is None or a in mesh.axis_names)
                   and a not in used)
        used.update(ax)
        axes.append(ax if len(ax) > 1 else (ax[0] if ax else None))
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


def _divides(array_dim: int, mesh, axis) -> bool:
    if axis is None or mesh is None:
        return True
    axes = (axis,) if isinstance(axis, str) else axis
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return array_dim % size == 0


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op w/o mesh).

    Axes that do not divide the corresponding dimension are silently
    dropped to None -- models with odd head/expert counts stay legal.
    """
    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or rules is None:
        return x
    spec = logical_to_spec(logical, rules, mesh)
    fixed = []
    for i, ax in enumerate(spec):
        fixed.append(ax if _divides(x.shape[i], mesh, ax) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

def is_logical_leaf(x) -> bool:
    """True for an (axes, shape) logical annotation.

    Strict: axes entries must be str / None / tuple-of-str and shape
    entries int / None.  (Loose checks mistake 2-field NamedTuples like
    KVCache or MambaState for leaves and silently replicate everything
    under them.)
    """
    if not (isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple) and isinstance(x[1], tuple)):
        return False
    axes, shape = x
    for a in axes:
        if a is None or isinstance(a, str):
            continue
        if isinstance(a, tuple) and a and all(isinstance(b, str) for b in a):
            continue
        return False
    return all(d is None or isinstance(d, int) for d in shape)


def param_sharding_tree(param_logical_tree, mesh: Mesh,
                        rules: Optional[AxisRules] = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    rules = rules or default_rules(multi_pod="pod" in mesh.axis_names)

    def to_sharding(logical_and_shape):
        logical, shape = logical_and_shape
        spec = logical_to_spec(logical, rules, mesh)
        fixed = []
        for i, ax in enumerate(spec):
            fixed.append(ax if _divides(shape[i], mesh, ax) else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(to_sharding, param_logical_tree,
                        is_leaf=is_logical_leaf)
