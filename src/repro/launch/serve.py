"""Serving driver: batched generation with FastAttention (+T4 offload),
or the persistent paged EngineCore (``--stream``).

    # dense static-batch generation (the original path)
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 64 --gen 16

    # iteration-level serving: EngineCore.add_request/step with
    # per-request SamplingParams (every 3rd request samples, seeded)
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --stream --requests 8 --prompt-len 24 --gen 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (ParallelConfig, ServeConfig, get_model_config,
                          reduce_for_smoke)
from repro.core.offload import OffloadLatencyModel, plan_offload
from repro.launch.mesh import make_mesh_for
from repro.models import build_model
from repro.serving.core import EngineCore
from repro.serving.engine import ServeEngine
from repro.serving.faults import RequestRejected
from repro.serving.scheduler import SamplingParams
from repro.sharding.rules import axis_rules


def _run_stream(model, params, cfg, args) -> None:
    """Drive the persistent EngineCore directly: submit a queue of
    requests with mixed per-request SamplingParams, step the engine,
    and print tokens as they stream out."""
    page_size = 128 if jax.default_backend() == "tpu" else 16
    serve = ServeConfig(
        max_batch=min(4, args.requests),
        max_seq_len=args.prompt_len + args.gen + page_size,
        page_size=page_size,
        max_waiting=args.max_waiting,
        queue_policy=args.queue_policy,
        spec_mode=args.spec_mode,
        spec_tokens=args.spec_tokens if args.spec_tokens > 0 else 4)
    core = EngineCore(model, params, cfg, serve)
    rng = np.random.default_rng(0)
    # --top-k 1 (the dense-path greedy default) would make the "sampled"
    # requests greedy too; give them a real truncation instead
    stream_top_k = args.top_k if args.top_k not in (0, 1) else 8
    deadline = args.deadline_ms if args.deadline_ms > 0 else None
    for i in range(args.requests):
        if i % 3 == 2:
            sp = SamplingParams(temperature=0.8, top_k=stream_top_k,
                                seed=i, max_new_tokens=args.gen,
                                deadline_ms=deadline)
        else:
            sp = SamplingParams(max_new_tokens=args.gen,
                                deadline_ms=deadline)   # greedy
        if args.spec_mode != "off":
            # prompt-lookup thrives on repetitive text; tile a short
            # motif so the demo shows a real accept rate
            motif = rng.integers(1, cfg.vocab_size, size=7).tolist()
            prompt = np.array(
                (motif * (args.prompt_len // 7 + 1))[:args.prompt_len],
                np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len)
        try:
            core.add_request(prompt, sp)
        except RequestRejected as e:
            # queue_policy="reject" surfaces a structured error at
            # submission; the engine keeps serving what it admitted
            print(f"rejected: {e.detail}")
    t0 = time.perf_counter()  # repro-lint: disable=raw-wall-clock (CLI wall time)
    n_events = 0
    while core.has_work:
        for ev in core.step():
            if ev.kind == "error":
                print(f"req {ev.request_id} failed: {ev.detail}")
                continue
            n_events += 1
            if ev.finished:
                print(f"req {ev.request_id} finished "
                      f"({ev.index + 1} tokens)")
    dt = time.perf_counter() - t0  # repro-lint: disable=raw-wall-clock (CLI wall time)
    s = core.stats()
    print(f"{n_events} tokens in {dt:.2f}s ({n_events / dt:.1f} tok/s), "
          f"{s['steps']} engine steps, peak pool "
          f"{s['pages_peak']}/{core.mgr.usable_pages} pages "
          f"({s['peak_utilization']:.0%}), "
          f"{s['pressure']['preemptions']} preemptions")
    # health printout sourced from the registry snapshot (the same
    # numbers stats()["health"] mirrors -- counters read their windows)
    snap = core.metrics.snapshot()

    def _w(name):
        m = snap.get(name)
        return m["window"] if m else 0

    hw = snap.get("engine_step_seconds", {}).get("max", 0.0)
    print(f"health: {_w('engine_requests_failed_total')} failed, "
          f"{_w('engine_requests_shed_total')} shed, "
          f"{_w('engine_requests_timed_out_total')} timed out, "
          f"{_w('pressure_swap_retries_total')} swap retries "
          f"({_w('pressure_swap_fail_downgrades_total')} downgraded to "
          f"recompute), slowest step {hw * 1e3:.1f}ms"
          + (f", last error: {s['health']['last_error']}"
             if s["health"]["last_error"] else ""))
    if "spec" in s:
        sp = s["spec"]
        print(f"speculation: {sp['accepted']}/{sp['drafted']} drafts "
              f"accepted ({sp['accept_rate']:.0%}) over "
              f"{sp['verify_launches']} verify launches")
    if core.tracer is not None and core.tracer.completed:
        ttfts = sorted(r["first_token_t"] - r["submit_t"]
                       for r in core.tracer.completed
                       if r["first_token_t"] is not None)
        if ttfts:
            print(f"engine-native TTFT: p50 "
                  f"{ttfts[len(ttfts) // 2] * 1e3:.1f}ms, max "
                  f"{ttfts[-1] * 1e3:.1f}ms over {len(ttfts)} requests")
    if args.metrics is not None:
        print("---- prometheus " + "-" * 48)
        print(core.export_prometheus(), end="")
        if args.metrics != "-":
            import json
            with open(args.metrics, "w") as f:
                json.dump(core.chrome_trace(), f)
            print(f"---- chrome trace ({len(core.flight.records)} steps) "
                  f"written to {args.metrics}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--top-k", type=int, default=1)
    ap.add_argument("--offload-report", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="serve through the paged EngineCore "
                         "(add_request/step) instead of dense generate")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests to stream (with --stream)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline in ms (0 = none; expired "
                         "requests are shed with a structured timeout)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="bound on the waiting queue (0 = unbounded)")
    ap.add_argument("--queue-policy", default="reject",
                    choices=["reject", "shed_oldest"],
                    help="full-queue policy: reject new arrivals or "
                         "shed the oldest waiting request")
    ap.add_argument("--spec-mode", default="off",
                    choices=["off", "lookup"],
                    help="with --stream: speculative decoding drafter "
                         "(lookup = prompt-lookup n-gram matching; "
                         "greedy output is bit-identical either way)")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="max draft tokens per request per step "
                         "(0 = engine default of 4; only with "
                         "--spec-mode lookup)")
    ap.add_argument("--metrics", nargs="?", const="-", default=None,
                    metavar="TRACE_JSON",
                    help="with --stream: print the Prometheus text "
                         "exposition at end of run; with a path, also "
                         "write the flight recorder's Chrome trace_event "
                         "JSON there (load in chrome://tracing)")
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    parallel = ParallelConfig()
    mesh = make_mesh_for(parallel)
    model = build_model(cfg, parallel)

    if args.offload_report:
        plan = plan_offload(cfg, batch=args.batch,
                            seq_len=args.prompt_len + args.gen,
                            gen_len=args.gen, n_devices=1)
        print("T4 offload plan:", plan.summary())

    with axis_rules(mesh=mesh):
        params = model.init(jax.random.PRNGKey(0))
        if args.stream:
            _run_stream(model, params, cfg, args)
            return
        serve = ServeConfig(max_seq_len=args.prompt_len + args.gen + 1,
                            top_k=args.top_k)
        engine = ServeEngine(model=model, params=params, cfg=cfg,
                             serve=serve)
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()  # repro-lint: disable=raw-wall-clock (CLI wall time)
        out = engine.generate(tokens, args.gen)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0  # repro-lint: disable=raw-wall-clock (CLI wall time)
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
