"""Serving driver: batched generation with FastAttention (+T4 offload).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import (ParallelConfig, ServeConfig, get_model_config,
                          reduce_for_smoke)
from repro.core.offload import OffloadLatencyModel, plan_offload
from repro.launch.mesh import make_mesh_for
from repro.models import build_model
from repro.serving.engine import ServeEngine
from repro.sharding.rules import axis_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--top-k", type=int, default=1)
    ap.add_argument("--offload-report", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    parallel = ParallelConfig()
    mesh = make_mesh_for(parallel)
    model = build_model(cfg, parallel)

    if args.offload_report:
        plan = plan_offload(cfg, batch=args.batch,
                            seq_len=args.prompt_len + args.gen,
                            gen_len=args.gen, n_devices=1)
        print("T4 offload plan:", plan.summary())

    with axis_rules(mesh=mesh):
        params = model.init(jax.random.PRNGKey(0))
        serve = ServeConfig(max_seq_len=args.prompt_len + args.gen + 1,
                            top_k=args.top_k)
        engine = ServeEngine(model=model, params=params, cfg=cfg,
                             serve=serve)
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        out = engine.generate(tokens, args.gen)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
