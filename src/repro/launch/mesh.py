"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Single pod: (data=16, model=16) = 256 chips.  Multi-pod:
(pod=2, data=16, model=16) = 512 chips, `pod` as the slow (DCN/ICI-bridge)
axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import ParallelConfig


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """jax.make_mesh with explicit Auto axis types where the installed
    jax supports them (jax.sharding.AxisType landed after 0.4.37; older
    versions are Auto-only, so omitting the kwarg is equivalent)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(parallel: ParallelConfig):
    """Mesh matching an arbitrary ParallelConfig (tests use small ones)."""
    return make_mesh(parallel.mesh_shape(), parallel.mesh_axes())


def parallel_for_mesh(mesh) -> ParallelConfig:
    s = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ParallelConfig(pods=s.get("pod", 1), data=s.get("data", 1),
                          model=s.get("model", 1))
