"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Single pod: (data=16, model=16) = 256 chips.  Multi-pod:
(pod=2, data=16, model=16) = 512 chips, `pod` as the slow (DCN/ICI-bridge)
axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(parallel: ParallelConfig):
    """Mesh matching an arbitrary ParallelConfig (tests use small ones)."""
    shape = parallel.mesh_shape()
    axes = parallel.mesh_axes()
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def parallel_for_mesh(mesh) -> ParallelConfig:
    s = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ParallelConfig(pods=s.get("pod", 1), data=s.get("data", 1),
                          model=s.get("model", 1))
