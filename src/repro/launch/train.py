"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 50 --batch 8 --seq 256 [--smoke]

Runs on whatever devices exist (CPU here, TPU pod in production):
data pipeline -> jit'd train step under the mesh + logical rules ->
checkpointing -> fault-tolerance hooks.  --smoke shrinks the arch to the
reduced config so a 100M-scale run finishes on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import (ParallelConfig, TrainConfig, get_model_config,
                          reduce_for_smoke)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh_for
from repro.models import build_model
from repro.sharding.rules import axis_rules, param_sharding_tree
from repro.training import optimizer as opt_mod
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import CadenceController, \
    StragglerDetector
from repro.training.train_step import TrainState, init_train_state, \
    make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    parallel = ParallelConfig(data=args.data, model=args.model_axis,
                              microbatches=args.microbatches,
                              remat="selective")
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every)
    mesh = make_mesh_for(parallel)
    model = build_model(cfg, parallel)
    ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
    cadence = CadenceController()
    stragglers = StragglerDetector()

    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))

    with axis_rules(mesh=mesh):
        state = init_train_state(model, jax.random.PRNGKey(tcfg.seed))
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            state, manifest = ckpt.restore(state)
            start = manifest["step"]
            data.restore(manifest["extras"]["data"])
            print(f"resumed from step {start}")
        params_sh = param_sharding_tree(model.logical(), mesh)
        state = TrainState(
            params=jax.device_put(state.params, params_sh),
            opt=opt_mod.AdamWState(
                step=state.opt.step,
                mu=jax.device_put(state.opt.mu, params_sh),
                nu=jax.device_put(state.opt.nu, params_sh)))
        step_fn = jax.jit(make_train_step(model, cfg, parallel, tcfg),
                          donate_argnums=(0,))

        host = "host0"
        with mesh:
            for step in range(start, args.steps):
                t0 = time.perf_counter()  # repro-lint: disable=raw-wall-clock (CLI wall time)
                batch = data.next()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = step_fn(state, batch)
                if step % 5 == 0 or step == args.steps - 1:
                    loss = float(metrics["loss"])
                    dt = time.perf_counter() - t0  # repro-lint: disable=raw-wall-clock
                    tok_s = args.batch * args.seq / dt
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):8.3f} "
                          f"{tok_s:9.0f} tok/s", flush=True)
                stragglers.record(host, time.perf_counter() - t0)  # repro-lint: disable=raw-wall-clock
                cadence.record_steps()
                every = min(tcfg.checkpoint_every, cadence.cadence())
                if (step + 1) % every == 0:
                    ckpt.save(step + 1, state,
                              extras={"data": data.state()}, async_=True)
        ckpt.wait()
        ckpt.save(args.steps, state, extras={"data": data.state()})
        print(f"done; final checkpoint at step {args.steps} "
              f"in {tcfg.checkpoint_dir}")


if __name__ == "__main__":
    main()
