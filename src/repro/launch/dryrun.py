import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory/cost/collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init); smoke tests and benchmarks never import this
module, so they keep seeing 1 device.
"""
import argparse            # noqa: E402
import dataclasses         # noqa: E402
import gzip                # noqa: E402
import json                # noqa: E402
import sys                 # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (SHAPES, TrainConfig, get_model_config)  # noqa: E402
from repro.launch import specs as S                        # noqa: E402
from repro.launch.mesh import make_production_mesh, parallel_for_mesh  # noqa: E402
from repro.models import build_model                       # noqa: E402
from repro.sharding.rules import (axis_rules, default_rules,  # noqa: E402
                                  param_sharding_tree)
from repro.training import optimizer as opt_mod            # noqa: E402
from repro.training.train_step import TrainState, make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _sharding_tree(logical_tree, mesh, rules):
    return param_sharding_tree(logical_tree, mesh, rules)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def skip_reason(arch: str, shape_name: str) -> str:
    """Documented cell skips (DESIGN.md §3)."""
    cfg = get_model_config(arch)
    if shape_name == "long_500k" and not cfg.is_subquadratic():
        return ("long_500k needs sub-quadratic attention; "
                f"{arch} has full/global attention layers")
    return ""


def build_cell(arch: str, shape_name: str, mesh, *, rules=None,
               parallel=None):
    """Construct (fn, args_sds, in_shardings) for one dry-run cell."""
    cfg = get_model_config(arch)
    shape = SHAPES[shape_name]
    parallel = parallel or parallel_for_mesh(mesh)
    model = build_model(cfg, parallel)
    rules = rules or default_rules(multi_pod="pod" in mesh.axis_names)

    params_sds = S.abstract_params(model)
    params_sh = _sharding_tree(model.logical(), mesh, rules)

    if shape.kind == "train":
        tcfg = TrainConfig()
        step = make_train_step(model, cfg, parallel, tcfg)
        opt_sds = jax.eval_shape(opt_mod.init_adamw, params_sds)
        state_sds = TrainState(params=params_sds, opt=opt_sds)
        state_sh = TrainState(
            params=params_sh,
            opt=opt_mod.AdamWState(step=_replicated(mesh), mu=params_sh,
                                   nu=params_sh))
        batch_sds = {k: v for k, v in S.batch_specs(cfg, shape).items()}
        batch_sh = _sharding_tree(S.batch_logical(cfg, shape), mesh, rules)
        if cfg.is_encoder_decoder or cfg.modality == "vision_stub":
            pass
        fn = step
        args = (state_sds, batch_sds)
        shardings = (state_sh, batch_sh)
        out_sh = (state_sh, None)
    elif shape.kind == "prefill":
        batch_sds = S.batch_specs(cfg, shape)
        batch_sh = _sharding_tree(S.batch_logical(cfg, shape), mesh, rules)
        if cfg.is_encoder_decoder:
            def fn(params, batch):
                return model.apply(params, batch["enc_embeds"],
                                   batch["tokens"])
        elif cfg.modality == "vision_stub":
            def fn(params, batch):
                return model.apply(params,
                                   inputs_embeds=batch["inputs_embeds"],
                                   positions=batch["positions"])
        else:
            def fn(params, batch):
                return model.apply(params, batch["tokens"])
        batch_sds.pop("labels", None)
        batch_sh.pop("labels", None)
        args = (params_sds, batch_sds)
        shardings = (params_sh, batch_sh)
        out_sh = None
    else:  # decode
        token_sds, cache_sds, pos_sds = S.decode_specs(cfg, shape, model)
        cache_sh = _sharding_tree(
            model.cache_logical(shape.global_batch, shape.seq_len),
            mesh, rules)

        def fn(params, token, cache, pos):
            return model.decode_step(params, token, cache, pos)

        token_sh = _sharding_tree(
            (("batch",), (shape.global_batch,)), mesh, rules)
        args = (params_sds, token_sds, cache_sds, pos_sds)
        shardings = (params_sh, token_sh, cache_sh, _replicated(mesh))
        # keep the updated cache in its input sharding: without this GSPMD
        # may materialize the scan's cache output gathered over `model`
        # (observed: 36 GB/dev temp for qwen2.5 decode_32k)
        out_sh = (None, cache_sh)
    return fn, args, shardings, out_sh, model, rules


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = RESULTS_DIR, save_hlo: bool = True,
             rules=None, tag: str = "") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}{tag}"
    reason = skip_reason(arch, shape_name)
    if reason:
        rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "status": "skipped", "reason": reason}
        _save(rec, out_dir, cell_id)
        return rec

    t0 = time.time()  # repro-lint: disable=raw-wall-clock (compile wall time)
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        with axis_rules(rules, mesh=mesh):
            fn, args, shardings, out_sh, model, rules_used = build_cell(
                arch, shape_name, mesh, rules=rules)
            shape_kind = SHAPES[shape_name].kind
            # serving donates the KV cache (in-place update); training
            # donates the train state (params/opt buffers reused)
            donate = (2,) if shape_kind == "decode" else (
                (0,) if shape_kind == "train" else ())
            with mesh:
                lowered = jax.jit(fn, in_shardings=shardings,
                                  out_shardings=out_sh,
                                  donate_argnums=donate).lower(*args)
                compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        rec = {
            "cell": cell_id, "arch": arch, "shape": shape_name,
            "mesh": mesh_name, "status": "ok",
            "n_devices": int(mesh.devices.size),
            "compile_s": round(time.time() - t0, 1),  # repro-lint: disable=raw-wall-clock
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "xla_cost": {"flops": cost.get("flops", 0.0),
                         "bytes_accessed": cost.get("bytes accessed", 0.0)},
        }
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            hlo_path = os.path.join(out_dir, cell_id + ".hlo.gz")
            with gzip.open(hlo_path, "wt") as f:
                f.write(compiled.as_text())
            rec["hlo"] = hlo_path
        # in-process roofline terms (uses our own HLO cost parser)
        try:
            from repro.analysis.roofline import roofline_from_hlo_text
            terms = roofline_from_hlo_text(
                compiled.as_text(), arch=arch, shape_name=shape_name,
                n_devices=int(mesh.devices.size))
            rec["roofline"] = terms
        except Exception as e:           # analysis must never fail the cell
            rec["roofline_error"] = f"{type(e).__name__}: {e}"
    except Exception as e:
        rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    _save(rec, out_dir, cell_id)
    return rec


def _save(rec: dict, out_dir: str, cell_id: str):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED_ARCHS
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                               save_hlo=not args.no_hlo)
                status = rec["status"]
                extra = rec.get("reason") or rec.get("error", "")
                print(f"[{status:7s}] {rec['cell']} "
                      f"({rec.get('compile_s', 0)}s) {extra}", flush=True)
                if status == "error":
                    failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
