"""input_specs(): ShapeDtypeStruct stand-ins for every model input --
weak-type-correct, shardable, zero device allocation.

For each (arch x shape) cell this returns the abstract arguments of the
step function the dry-run lowers:
  train    -> train_step(state, batch)
  prefill  -> apply(params, tokens/embeds)
  decode   -> decode_step(params, token, cache, pos)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig, ShapeConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract training/prefill batch for an architecture."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {
            "enc_embeds": sds((b, cfg.encoder_seq, cfg.d_model),
                              jnp.bfloat16),
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
    if cfg.modality == "vision_stub":
        return {
            "inputs_embeds": sds((b, s, cfg.d_model), jnp.bfloat16),
            "positions": sds((3, b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
    return {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }


def batch_logical(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Logical axes for the batch (batch dim sharded over DP)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {
            "enc_embeds": (("batch", None, None),
                           (b, cfg.encoder_seq, cfg.d_model)),
            "tokens": (("batch", None), (b, s)),
            "labels": (("batch", None), (b, s)),
        }
    if cfg.modality == "vision_stub":
        return {
            "inputs_embeds": (("batch", None, None), (b, s, cfg.d_model)),
            "positions": ((None, "batch", None), (3, b, s)),
            "labels": (("batch", None), (b, s)),
        }
    return {"tokens": (("batch", None), (b, s)),
            "labels": (("batch", None), (b, s))}


def abstract_params(model) -> Any:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(model.init, key)


def abstract_cache(model, batch: int, max_seq: int) -> Any:
    if model.cfg.is_encoder_decoder:
        return jax.eval_shape(
            lambda: model.init_cache(batch, max_seq))
    return jax.eval_shape(lambda: model.init_cache(batch, max_seq))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model):
    b, s = shape.global_batch, shape.seq_len
    token = sds((b,), jnp.int32)
    cache = abstract_cache(model, b, s)
    pos = sds((), jnp.int32)
    return token, cache, pos
