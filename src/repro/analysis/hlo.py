"""HLO-text cost analysis with while-loop trip-count inference.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE,
which under-reports every lax.scan (layer stacks, flash KV chunks, grad
accumulation) by its trip count.  This module re-derives FLOPs / HBM bytes
/ collective bytes directly from ``compiled.as_text()``:

  * dots:        2 * prod(result) * contracted_size
  * elementwise: prod(result)
  * reduces:     prod(operand)
  * bytes:       operands + results at fusion boundaries (fusion internals
                 live in registers/VMEM and do not touch HBM)
  * collectives: per-op operand bytes, bucketed by opcode
  * while loops: body+condition costs multiplied by the inferred trip count
                 (jax scans lower to `iv < constant` conditions; fallback 1)
  * conditionals: max over branches.

Shapes in post-SPMD HLO are per-device shard shapes, so totals are
per-device -- exactly what the roofline terms need.

Validated against cost_analysis() on scan-free graphs (tests/test_hlo.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "tanh", "negate", "power", "rsqrt", "sqrt", "log",
    "logistic", "select", "compare", "and", "or", "not", "xor", "convert",
    "floor", "ceil", "sign", "cosine", "sine", "clamp", "remainder",
    "round-nearest-even", "round-nearest-afz", "expm1", "log1p", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "exponential-minus-one",
}
_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "broadcast", "iota", "copy", "copy-start",
    "copy-done", "after-all", "partition-id", "replica-id", "domain",
    "opt-barrier", "custom-call", "rng", "rng-bit-generator", "infeed",
    "outfeed", "send", "recv", "send-done", "recv-done", "add-dependency",
}


def _shape_info(type_str: str) -> Tuple[float, float]:
    """(total elements, total bytes) over all arrays in a type string."""
    elems = 0.0
    bytes_ = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * DTYPE_BYTES[dtype]
    return elems, bytes_


@dataclass
class Instruction:
    name: str
    opcode: str
    result_type: str
    operands_str: str
    attrs: str

    @property
    def result_elems(self):
        return _shape_info(self.result_type)[0]

    @property
    def result_bytes(self):
        return _shape_info(self.result_type)[1]

    def operand_names(self) -> List[str]:
        """Operand instruction names at paren depth 0 (typed or untyped)."""
        out = []
        depth = 0
        token = []
        for ch in self.operands_str + ",":
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
            if ch == "," and depth == 0:
                t = "".join(token).strip()
                token = []
                m = re.search(r"%?([\w\.\-]+)$", t)
                if m:
                    out.append(m.group(1))
                continue
            token.append(ch)
        return out

    def operand_types(self, symbols: Dict[str, str]) -> List[str]:
        """Resolve operand types: inline if typed, else via symbol table."""
        inline = _SHAPE_RE.findall(self.operands_str)
        if inline:
            # operands carry inline types in this printing; commas inside
            # shape brackets ("f32[128,256]") must not split tokens
            depth = 0
            toks, token = [], []
            for ch in self.operands_str + ",":
                if ch in "({[":
                    depth += 1
                elif ch in ")}]":
                    depth -= 1
                if ch == "," and depth == 0:
                    toks.append("".join(token).strip())
                    token = []
                    continue
                token.append(ch)
            return toks
        return [symbols.get(n, "") for n in self.operand_names()]

    def operand_bytes_resolved(self, symbols: Dict[str, str]) -> float:
        return sum(_shape_info(t)[1] for t in self.operand_types(symbols))

    def called(self) -> List[str]:
        out = []
        for m in re.finditer(
                r"(?:calls|body|condition|to_apply|branch_computations)="
                r"(\{[^}]*\}|%?[\w\.\-]+)", self.attrs):
            v = m.group(1)
            if v.startswith("{"):
                out += [s.strip().lstrip("%")
                        for s in v[1:-1].split(",") if s.strip()]
            else:
                out.append(v.lstrip("%"))
        # true/false computations (older conditional syntax)
        for m in re.finditer(r"(?:true|false)_computation=%?([\w\.\-]+)",
                             self.attrs):
            out.append(m.group(1))
        return out


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)

    @property
    def symbols(self) -> Dict[str, str]:
        if not hasattr(self, "_symbols"):
            self._symbols = {i.name: i.result_type
                             for i in self.instructions}
        return self._symbols


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = field(default_factory=dict)
    top_collectives: List[Tuple[str, float, int]] = field(
        default_factory=list)   # (opcode, bytes_one_call, n_calls)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.transcendentals += other.transcendentals * times
        self.collective_bytes += other.collective_bytes * times
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0) + v * times
        for op, b, n in other.top_collectives:
            self.top_collectives.append((op, b, int(n * times)))


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        ls = line.strip()
        # computation header: "[ENTRY] %name (params...) -> type {"
        if (ls.endswith("{") and "->" in ls and " = " not in ls
                and not ls.startswith("HloModule")):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", ls)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None or " = " not in ls:
            continue
        inst = _parse_instruction(ls)
        if inst is not None:
            cur.instructions.append(inst)
    return comps, entry


def _parse_instruction(line: str) -> Optional[Instruction]:
    ls = line.strip()
    if ls.startswith("ROOT "):
        ls = ls[5:]
    m = re.match(r"^%?([\w\.\-]+)\s*=\s*", ls)
    if not m:
        return None
    name = m.group(1)
    rest = ls[m.end():]
    # balanced-paren type (tuples) or plain type
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        result_type = rest[:i + 1]
        rest = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        result_type = rest[:sp]
        rest = rest[sp + 1:].strip()
    m2 = re.match(r"^([\w\-]+)\(", rest)
    if not m2:
        return None
    opcode = m2.group(1)
    depth = 0
    for i in range(m2.end() - 1, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operands = rest[m2.end():i]
    attrs = rest[i + 1:]
    return Instruction(name, opcode, result_type, operands, attrs)


# ---------------------------------------------------------------------------
# trip-count inference
# ---------------------------------------------------------------------------

def _constants(comp: Computation) -> Dict[str, float]:
    out = {}
    for inst in comp.instructions:
        if inst.opcode == "constant":
            m = re.match(r"^\s*([\-\d\.e\+]+)", inst.operands_str)
            if m:
                try:
                    out[inst.name] = float(m.group(1))
                except ValueError:
                    pass
    return out


def infer_trip_count(cond: Computation,
                     comps: Optional[Dict[str, Computation]] = None) -> int:
    """Trip count of a jax-scan-style while: `iv < constant` condition.

    Post-optimization the compare usually sits inside a kLoop fusion with
    the limit constant passed as a fusion operand, so we search the
    condition computation and its called computations, and fall back to the
    last integer scalar constant in the condition computation.
    """
    comps = comps or {}
    consts = _constants(cond)
    search = [cond]
    for inst in cond.instructions:
        for name in inst.called():
            if name in comps:
                search.append(comps[name])

    direction = None
    for comp in search:
        local_consts = {**consts, **_constants(comp)}
        for inst in comp.instructions:
            if inst.opcode != "compare":
                continue
            mdir = re.search(r"direction=(\w+)", inst.attrs)
            direction = mdir.group(1) if mdir else "LT"
            vals = [local_consts.get(o) for o in inst.operand_names()]
            const_vals = [v for v in vals if v is not None]
            if const_vals:
                c = const_vals[-1]
                if direction == "LE":
                    return max(int(c) + 1, 1)
                return max(int(c), 1)
    # fallback: compare operands were fusion parameters -- use the last
    # integer scalar constant of the condition computation (the limit is
    # materialized there and passed into the fusion).
    int_consts = []
    for inst in cond.instructions:
        if inst.opcode == "constant" and re.match(
                r"^[su]\d+\[\]", inst.result_type):
            m = re.match(r"^\s*([\-\d]+)", inst.operands_str)
            if m:
                int_consts.append(int(m.group(1)))
    if int_consts:
        c = int_consts[-1]
        if direction == "LE":
            return max(c + 1, 1)
        return max(c, 1)
    return 1


# ---------------------------------------------------------------------------
# cost walk
# ---------------------------------------------------------------------------

def _dot_flops(inst: Instruction, symbols: Dict[str, str]) -> float:
    res_elems = inst.result_elems
    types = inst.operand_types(symbols)
    lhs_dims: List[int] = []
    if types:
        m0 = _SHAPE_RE.search(types[0])
        if m0 and m0.group(2):
            lhs_dims = [int(d) for d in m0.group(2).split(",")]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    csize = 1.0
    if m and m.group(1):
        for i in m.group(1).split(","):
            if int(i) < len(lhs_dims):
                csize *= lhs_dims[int(i)]
    return 2.0 * res_elems * csize


def _conv_flops(inst: Instruction, symbols: Dict[str, str]) -> float:
    # result elems * 2 * (kernel spatial * in_channels); approximate via
    # rhs operand elements / out_channels
    types = inst.operand_types(symbols)
    if len(types) < 2:
        return 0.0
    m1 = _SHAPE_RE.search(types[1])
    rhs = [int(d) for d in m1.group(2).split(",")] \
        if (m1 and m1.group(2)) else []
    res = inst.result_elems
    if not rhs:
        return 0.0
    import numpy as np
    return 2.0 * res * float(np.prod(rhs[:-1])) if len(rhs) > 1 else res


_SLICERS = ("dynamic-slice", "slice", "gather")


def _fusion_io_bytes(inst: Instruction, sym: Dict[str, str],
                     fused: Optional[Computation]) -> float:
    """HBM traffic of one fusion call.

    A fusion parameter that is only ever *sliced* inside the fusion reads
    just the sliced region (scan xs buffers!); a dynamic-update-slice root
    writes only the updated region (scan carry buffers are aliased).
    """
    optypes = inst.operand_types(sym)
    if fused is None:
        return inst.result_bytes + sum(_shape_info(t)[1] for t in optypes)
    fsym = fused.symbols
    params: Dict[int, Instruction] = {}
    for fi in fused.instructions:
        if fi.opcode == "parameter":
            m = re.match(r"^\s*(\d+)", fi.operands_str)
            if m:
                params[int(m.group(1))] = fi
    # inside a fusion nothing materializes, so layout/shape ops are views.
    # `convert` included: the CPU backend emulates bf16 by inserting
    # f32<->bf16 converts (whole-cache/-weight copies per scan iteration)
    # that do not exist in the TPU-native bf16 program we are modeling.
    view_ops = ("bitcast", "reshape", "bitcast-convert", "copy",
                "transpose", "convert")
    total = 0.0
    for i, t in enumerate(optypes):
        full = _shape_info(t)[1]
        p = params.get(i)
        if p is None:
            total += full
            continue
        # follow the param through view ops; if every terminal consumer is
        # a slice (or a DUS targeting it), charge only the sliced bytes
        frontier = {p.name}
        slice_only = True
        sliced = 0.0
        seen = set()
        any_consumer = False
        while frontier and slice_only:
            nxt = set()
            for fi in fused.instructions:
                if fi.name in seen:
                    continue
                onames = fi.operand_names()
                if not (frontier & set(onames)):
                    continue
                any_consumer = True
                seen.add(fi.name)
                if fi.opcode in _SLICERS:
                    sliced += fi.result_bytes
                elif (fi.opcode == "dynamic-update-slice"
                      and onames[:1] and onames[0] in frontier):
                    ts = fi.operand_types(fsym)
                    sliced += _shape_info(ts[1])[1] if len(ts) > 1 else 0.0
                elif fi.opcode in view_ops:
                    nxt.add(fi.name)
                else:
                    slice_only = False
                    break
            frontier = nxt
        total += min(sliced, full) if (slice_only and any_consumer) else full
    # walk the root back through view ops: convert(DUS(...)) roots still
    # write only the updated region (the buffer is aliased in place)
    root = fused.instructions[-1] if fused.instructions else None
    by_name = {fi.name: fi for fi in fused.instructions}
    hops = 0
    while (root is not None and root.opcode in view_ops + ("convert",)
           and hops < 8):
        ops = root.operand_names()
        root = by_name.get(ops[0]) if ops else None
        hops += 1
    if root is not None and root.opcode == "dynamic-update-slice":
        ts = root.operand_types(fsym)
        total += 2 * (_shape_info(ts[1])[1] if len(ts) > 1 else 0.0)
    elif root is not None and root.opcode == "parameter":
        pass   # pure convert/layout fusion: absent on bf16-native TPU
    else:
        total += inst.result_bytes
    return total


def computation_cost(name: str, comps: Dict[str, Computation],
                     memo: Dict[str, Cost], *, in_fusion: bool = False
                     ) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    cost = Cost()
    if comp is None:
        memo[name] = cost
        return cost
    sym = comp.symbols
    for inst in comp.instructions:
        op = inst.opcode
        if op == "while":
            body, cond_name = None, None
            for called in inst.called():
                if "cond" in called and cond_name is None:
                    cond_name = called
                else:
                    body = body or called
            mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
            mc = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
            body = mb.group(1) if mb else body
            cond_name = mc.group(1) if mc else cond_name
            trips = infer_trip_count(comps[cond_name], comps) \
                if cond_name in comps else 1
            inner = Cost()
            inner.add(computation_cost(body, comps, memo))
            if cond_name:
                inner.add(computation_cost(cond_name, comps, memo))
            cost.add(inner, times=trips)
        elif op == "conditional":
            branches = [computation_cost(c, comps, memo)
                        for c in inst.called()]
            if branches:
                best = max(branches, key=lambda c: c.flops + c.bytes)
                cost.add(best)
        elif op in ("fusion",):
            for c in inst.called():
                cost.add(computation_cost(c, comps, memo, in_fusion=True))
            fused = comps.get(inst.called()[0]) if inst.called() else None
            cost.bytes += _fusion_io_bytes(inst, sym, fused)
        elif op in ("call", "async-start", "async-done"):
            for c in inst.called():
                cost.add(computation_cost(c, comps, memo))
        elif any(op.startswith(c) for c in COLLECTIVES):
            if op.endswith("-done"):
                continue                     # counted at -start
            b = inst.operand_bytes_resolved(sym)
            base = op.replace("-start", "")
            cost.collective_bytes += b
            cost.by_collective[base] = cost.by_collective.get(base, 0) + b
            cost.top_collectives.append((base, b, 1))
            cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        elif op == "dot":
            cost.flops += _dot_flops(inst, sym)
            if not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        elif op == "convolution":
            cost.flops += _conv_flops(inst, sym)
            if not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        elif op in ("reduce", "reduce-window"):
            cost.flops += _shape_info(inst.operands_str)[0]
            if not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        elif op in _ELEMENTWISE:
            cost.flops += inst.result_elems
            if op in ("exponential", "tanh", "logistic", "log", "power",
                      "rsqrt", "sqrt", "cosine", "sine", "expm1", "log1p"):
                cost.transcendentals += inst.result_elems
            if not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        elif op in ("dynamic-slice", "slice", "gather"):
            # reads only the sliced/gathered region (~= result), not the
            # whole operand buffer (critical inside scan bodies, where the
            # operand is the full stacked xs array every iteration)
            if not in_fusion:
                cost.bytes += 2 * inst.result_bytes
        elif op == "dynamic-update-slice":
            # reads the update + writes the region; the big buffer aliases
            if not in_fusion:
                types = inst.operand_types(sym)
                upd = _shape_info(types[1])[1] if len(types) > 1 else 0.0
                cost.bytes += 2 * upd
        elif op == "scatter":
            if not in_fusion:
                types = inst.operand_types(sym)
                upd = _shape_info(types[-1])[1] if types else 0.0
                cost.bytes += 3 * upd
        elif op in ("concatenate", "pad", "transpose", "sort",
                    "select-and-scatter", "reverse", "dynamic-reshape",
                    "cumsum"):
            if not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        elif op in _ZERO_COST:
            if op == "custom-call" and not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
        else:
            if not in_fusion:
                cost.bytes += inst.result_bytes + inst.operand_bytes_resolved(sym)
    # keep only the biggest collective records to bound memory
    cost.top_collectives = sorted(cost.top_collectives,
                                  key=lambda t: -t[1] * max(t[2], 1))[:20]
    memo[name] = cost
    return cost


def analyze_hlo_text(text: str) -> Cost:
    comps, entry = parse_hlo(text)
    if entry is None:
        # pick the largest computation as entry
        entry = max(comps, key=lambda k: len(comps[k].instructions),
                    default=None)
    memo: Dict[str, Cost] = {}
    return computation_cost(entry, comps, memo)
