"""repro-lint CLI.

    PYTHONPATH=src python -m repro.analysis.lint src/ tests/
    PYTHONPATH=src python -m repro.analysis.lint src/ --format=json
    PYTHONPATH=src python -m repro.analysis.lint --list-rules

Exit status: 0 when no active error-severity finding, 1 otherwise,
2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

from repro.analysis.lint.framework import LintEngine, LintResult, Rule
from repro.analysis.lint.report import render_json, render_text
from repro.analysis.lint.rules import ALL_RULES, RULE_INDEX

__all__ = ["build_rules", "main"]


def build_rules(select: Optional[Sequence[str]] = None,
                ignore: Sequence[str] = (),
                severity: Sequence[str] = ()) -> List[Rule]:
    """Instantiate the configured rule set.

    ``select`` keeps only the named rules (None = all), ``ignore`` drops
    names, ``severity`` entries look like ``rule=warning``.
    """
    known = set(RULE_INDEX)
    for name in list(select or ()) + list(ignore):
        if name not in known:
            raise ValueError(f"unknown rule {name!r} "
                             f"(known: {', '.join(sorted(known))})")
    overrides = {}
    for spec in severity:
        if "=" not in spec:
            raise ValueError(f"--severity expects rule=level, got "
                             f"{spec!r}")
        name, level = spec.split("=", 1)
        if name not in known:
            raise ValueError(f"unknown rule {name!r} in --severity")
        if level not in ("error", "warning"):
            raise ValueError(f"severity must be error|warning, got "
                             f"{level!r}")
        overrides[name] = level
    rules: List[Rule] = []
    for cls in ALL_RULES:
        if select is not None and cls.name not in select:
            continue
        if cls.name in ignore:
            continue
        rule = cls()
        if cls.name in overrides:
            rule.severity = overrides[cls.name]
        rules.append(rule)
    return rules


def _list_rules() -> str:
    lines = []
    for cls in ALL_RULES:
        scope = ", ".join(cls.paths) if cls.paths else "all files"
        lines.append(f"{cls.code}  {cls.name}  [{cls.severity}; "
                     f"scope: {scope}]")
        lines.append(f"    {' '.join(cls.description.split())}")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: project-specific static analysis "
                    "encoding the engine's bug taxonomy")
    ap.add_argument("paths", nargs="*", default=(),
                    help="files or directories to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="run only the named rule "
                    "(repeatable)")
    ap.add_argument("--ignore", action="append", default=[],
                    metavar="RULE", help="skip the named rule "
                    "(repeatable)")
    ap.add_argument("--severity", action="append", default=[],
                    metavar="RULE=LEVEL",
                    help="override a rule's severity (error|warning)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="include suppressed findings in text output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (try: src/ tests/)",
              file=sys.stderr)
        return 2
    try:
        rules = build_rules(select=args.select, ignore=args.ignore,
                            severity=args.severity)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    result: LintResult = LintEngine(rules).run(args.paths)
    if args.format == "json":
        print(render_json(result))
    else:
        print(render_text(result, show_suppressed=args.show_suppressed))
    return 1 if result.errors else 0


if __name__ == "__main__":
    sys.exit(main())
