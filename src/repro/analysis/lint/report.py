"""Reporters for repro-lint: human text and machine JSON."""
from __future__ import annotations

import json
from typing import Dict, List

from repro.analysis.lint.framework import Finding, LintResult

__all__ = ["render_text", "render_json"]


def render_text(result: LintResult, *, show_suppressed: bool = False
                ) -> str:
    lines: List[str] = []
    shown = result.findings if show_suppressed else result.active
    for f in shown:
        tag = " (suppressed)" if f.suppressed else ""
        lines.append(f"{f.location()}: {f.severity} "
                     f"[{f.code} {f.rule}]{tag} {f.message}")
    by_rule: Dict[str, int] = {}
    for f in result.active:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    summary = (", ".join(f"{n} {rule}" for rule, n in sorted(
        by_rule.items())) or "clean")
    lines.append(f"repro-lint: {result.files_checked} files, "
                 f"{len(result.active)} findings "
                 f"({len(result.suppressed)} suppressed): {summary}")
    return "\n".join(lines)


def render_json(result: LintResult) -> str:
    payload = {
        "tool": "repro-lint",
        "version": 1,
        "files_checked": result.files_checked,
        "findings": [f.to_json() for f in result.active],
        "suppressed": [f.to_json() for f in result.suppressed],
        "summary": {
            "errors": len(result.errors),
            "warnings": len([f for f in result.active
                             if f.severity != "error"]),
            "suppressed": len(result.suppressed),
        },
    }
    return json.dumps(payload, indent=2, sort_keys=True)
