"""repro-lint: stdlib-ast static analysis encoding the serving
engine's observed bug taxonomy (see :mod:`repro.analysis.lint.rules`).

    PYTHONPATH=src python -m repro.analysis.lint src/ tests/
"""
from repro.analysis.lint.framework import (Finding, LintEngine,
                                           LintResult, ModuleContext,
                                           Rule)
from repro.analysis.lint.report import render_json, render_text
from repro.analysis.lint.rules import ALL_RULES, RULE_INDEX, default_rules

__all__ = ["Finding", "LintEngine", "LintResult", "ModuleContext",
           "Rule", "ALL_RULES", "RULE_INDEX", "default_rules",
           "render_json", "render_text", "lint_paths"]


def lint_paths(*paths: str) -> LintResult:
    """Convenience: run the default rule set over ``paths``."""
    return LintEngine(default_rules()).run(list(paths))
