"""repro-lint framework: single-pass AST visitor engine + rule registry.

The serving stack's bug history is statically detectable: PR 6 shipped a
never-imported name in an ``except`` clause (a latent NameError on a
rarely-taken path), PR 8 removed a stray ``time.perf_counter()`` from
``EngineCore.step`` that corrupted phase telemetry, PR 2 audited the
tree for shared mutable dataclass defaults.  Each hard-won runtime
assertion ("metrics are never jit-traced", "all engine timing goes
through ``self._clock``") becomes a compile-time CI gate here.

Design:

* stdlib-``ast`` only -- no third-party dependencies, importable and
  runnable anywhere the repo is.
* one parse + one tree walk per module: rules register the node types
  they care about (``node_types``) and the engine dispatches each node
  to every interested rule during a single traversal.  Shared analyses
  (parent links, module-level bindings, per-scope local names) are
  computed once on the :class:`ModuleContext` and reused by all rules.
* per-rule severity and config: every rule carries a ``config`` dict
  seeded from ``default_config`` and a ``severity`` that the CLI can
  override (``--severity rule=warning``).
* inline suppressions: ``# repro-lint: disable=<rule>[,<rule>]`` on the
  offending line (or on a comment line directly above it) suppresses
  matching findings on that line; ``# repro-lint: disable-file=<rule>``
  anywhere in the first ``FILE_PRAGMA_LINES`` lines suppresses the rule
  for the whole module.  ``disable=all`` suppresses every rule.
* cross-module rules: after every module is swept, each rule's
  ``finalize()`` runs once (metric-name uniqueness needs the whole
  tree's creation sites).
"""
from __future__ import annotations

import ast
import builtins
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "Rule", "ModuleContext", "LintEngine",
           "dotted_name", "iter_child_nodes_deep"]

FILE_PRAGMA_LINES = 12
_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([A-Za-z0-9_\-, ]+)")

_BUILTIN_NAMES = frozenset(dir(builtins))


@dataclass
class Finding:
    """One lint finding, pointing at a file:line."""
    rule: str                  # kebab-case rule name (the disable token)
    code: str                  # stable REPROxxx identifier
    severity: str              # "error" | "warning"
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "code": self.code,
                "severity": self.severity, "path": self.path,
                "line": self.line, "col": self.col,
                "message": self.message, "suppressed": self.suppressed}


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_child_nodes_deep(node: ast.AST) -> Iterable[ast.AST]:
    """``ast.walk`` minus the root."""
    for child in ast.walk(node):
        if child is not node:
            yield child


# ---------------------------------------------------------------------------
# per-module shared analyses
# ---------------------------------------------------------------------------

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _bound_names(node: ast.AST) -> Set[str]:
    """Names bound by one statement/expression (targets, imports,
    defs); does not recurse into nested scopes."""
    names: Set[str] = set()
    if isinstance(node, (ast.Import, ast.ImportFrom)):
        for alias in node.names:
            if alias.name == "*":
                continue
            names.add((alias.asname or alias.name).split(".")[0])
    elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        names.add(node.name)
    elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                           ast.For, ast.AsyncFor)):
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    names.add(n.id)
    elif isinstance(node, (ast.With, ast.AsyncWith)):
        for item in node.items:
            if item.optional_vars is not None:
                for n in ast.walk(item.optional_vars):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
    elif isinstance(node, ast.ExceptHandler):
        if node.name:
            names.add(node.name)
    elif isinstance(node, (ast.Global, ast.Nonlocal)):
        names.update(node.names)
    elif isinstance(node, ast.NamedExpr):
        if isinstance(node.target, ast.Name):
            names.add(node.target.id)
    elif isinstance(node, ast.comprehension):
        for n in ast.walk(node.target):
            if isinstance(n, ast.Name):
                names.add(n.id)
    elif isinstance(node, ast.MatchAs):
        if node.name:
            names.add(node.name)
    return names


def _scope_locals(scope: ast.AST) -> Set[str]:
    """Every name bound anywhere inside ``scope`` (params, assignments,
    for/with/except targets, comprehension targets, nested def names),
    recursing through nested scopes too -- deliberately loose: the
    unresolvable-except rule must never flag a name that *any* enclosing
    binding could provide."""
    names: Set[str] = set()
    if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
        a = scope.args
        for arg in (list(a.posonlyargs) + list(a.args)
                    + list(a.kwonlyargs)
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            names.add(arg.arg)
    for n in ast.walk(scope):
        names.update(_bound_names(n))
    return names


class ModuleContext:
    """Everything rules need about one parsed module, computed once."""

    def __init__(self, path: str, rel: str, source: str, tree: ast.Module):
        self.path = path
        self.rel = rel                      # forward-slash path for scoping
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        # names importable/bound at module level (cached), plus builtins
        self._module_names: Optional[Set[str]] = None
        self._scope_cache: Dict[ast.AST, Set[str]] = {}
        self.line_suppressions: Dict[int, Set[str]] = {}
        self.file_suppressions: Set[str] = set()
        self._parse_suppressions()

    # -- suppressions --------------------------------------------------
    def _parse_suppressions(self) -> None:
        pending: Set[str] = set()       # from standalone comment lines
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            stripped = line.strip()
            if m:
                rules = {r.strip() for r in m.group(2).split(",")
                         if r.strip()}
                if m.group(1) == "disable-file":
                    if i <= FILE_PRAGMA_LINES:
                        self.file_suppressions |= rules
                    continue
                self.line_suppressions.setdefault(i, set()).update(rules)
                if stripped.startswith("#"):
                    # standalone comment: also applies to the next
                    # non-comment line
                    pending |= rules
                continue
            if pending and stripped and not stripped.startswith("#"):
                self.line_suppressions.setdefault(i, set()).update(pending)
                pending = set()

    def is_suppressed(self, rule: str, line: int) -> bool:
        for pool in (self.file_suppressions,
                     self.line_suppressions.get(line, ())):
            if rule in pool or "all" in pool:
                return True
        return False

    # -- shared name analyses ------------------------------------------
    @property
    def module_names(self) -> Set[str]:
        if self._module_names is None:
            names: Set[str] = set(_BUILTIN_NAMES)
            for node in ast.walk(self.tree):
                names.update(_bound_names(node))
            self._module_names = names
        return self._module_names

    def enclosing_scopes(self, node: ast.AST) -> List[ast.AST]:
        """Function/lambda scopes around ``node``, innermost first."""
        scopes: List[ast.AST] = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, _SCOPE_NODES):
                scopes.append(cur)
            cur = self.parents.get(cur)
        return scopes

    def scope_locals(self, scope: ast.AST) -> Set[str]:
        if scope not in self._scope_cache:
            self._scope_cache[scope] = _scope_locals(scope)
        return self._scope_cache[scope]

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = self.parents.get(cur)
        return None

    def imported_modules(self) -> Dict[str, str]:
        """local alias -> imported module path (``import time as t`` ->
        {"t": "time"}); ``from x import y`` -> {"y": "x.y"}."""
        out: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    out[local] = alias.name if alias.asname else \
                        alias.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    out[alias.asname or alias.name] = \
                        f"{node.module}.{alias.name}"
        return out


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

class Rule:
    """Base class: subclasses set ``name``/``code``/``description``,
    register ``node_types`` and implement ``visit``.  ``paths`` scopes a
    rule to files whose path contains any of the given fragments (empty
    = every file).  State for cross-module checks accumulates on the
    instance; ``finalize`` yields whole-run findings."""

    name: str = ""
    code: str = ""
    description: str = ""
    severity: str = "error"
    paths: Tuple[str, ...] = ()
    node_types: Tuple[type, ...] = ()
    default_config: Dict[str, object] = {}

    def __init__(self, **config):
        self.config = dict(self.default_config)
        self.config.update(config)

    def applies_to(self, ctx: ModuleContext) -> bool:
        if not self.paths:
            return True
        return any(p in ctx.rel for p in self.paths)

    def start_module(self, ctx: ModuleContext) -> None:
        pass

    def visit(self, node: ast.AST, ctx: ModuleContext
              ) -> Iterable[Finding]:
        return ()

    def finish_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        return ()

    def finalize(self) -> Iterable[Finding]:
        return ()

    def finding(self, ctx: ModuleContext, node: ast.AST, message: str,
                *, line: Optional[int] = None) -> Finding:
        ln = line if line is not None else getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(rule=self.name, code=self.code,
                       severity=self.severity, path=ctx.rel, line=ln,
                       col=col + 1, message=message)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)
    files_checked: int = 0

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.active if f.severity == "error"]


class LintEngine:
    """Runs a set of rules over a file tree in a single AST pass per
    module, applies suppressions, and aggregates cross-module state."""

    def __init__(self, rules: Sequence[Rule]):
        self.rules = list(rules)

    # -- file discovery ------------------------------------------------
    @staticmethod
    def discover(paths: Sequence[str]) -> List[str]:
        files: List[str] = []
        for p in paths:
            if os.path.isfile(p):
                if p.endswith(".py"):
                    files.append(p)
                continue
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith(".")
                                 and d != "__pycache__")
                for n in sorted(names):
                    if n.endswith(".py"):
                        files.append(os.path.join(root, n))
        return files

    @staticmethod
    def _rel(path: str) -> str:
        rel = os.path.relpath(path)
        if rel.startswith(".."):
            rel = path
        return rel.replace(os.sep, "/")

    # -- the sweep -----------------------------------------------------
    def run(self, paths: Sequence[str]) -> LintResult:
        result = LintResult()
        for path in self.discover(paths):
            result.files_checked += 1
            rel = self._rel(path)
            try:
                with open(path, "r", encoding="utf-8") as f:
                    source = f.read()
                tree = ast.parse(source, filename=path)
            except (SyntaxError, ValueError, UnicodeDecodeError) as e:
                line = getattr(e, "lineno", 1) or 1
                result.findings.append(Finding(
                    rule="syntax-error", code="REPRO000",
                    severity="error", path=rel, line=line, col=1,
                    message=f"could not parse: {e.__class__.__name__}: "
                            f"{e}"))
                continue
            ctx = ModuleContext(path, rel, source, tree)
            self._run_module(ctx, result)
        for rule in self.rules:
            for f in rule.finalize():
                result.findings.append(f)
        result.findings.sort(key=lambda f: (f.path, f.line, f.col,
                                            f.rule))
        return result

    def _run_module(self, ctx: ModuleContext, result: LintResult) -> None:
        live = [r for r in self.rules if r.applies_to(ctx)]
        if not live:
            return
        for rule in live:
            rule.start_module(ctx)
        # one walk, dispatch by node type
        interest: Dict[type, List[Rule]] = {}
        for rule in live:
            for nt in rule.node_types:
                interest.setdefault(nt, []).append(rule)
        found: List[Finding] = []
        for node in ast.walk(ctx.tree):
            for rule in interest.get(type(node), ()):
                found.extend(rule.visit(node, ctx))
        for rule in live:
            found.extend(rule.finish_module(ctx))
        for f in found:
            f.suppressed = ctx.is_suppressed(f.rule, f.line)
            result.findings.append(f)
