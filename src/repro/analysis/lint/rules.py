"""The repro-lint rule catalogue: the engine's observed bug taxonomy.

Each rule encodes a bug class this repo has actually shipped (and fixed)
or a guarantee its equivalence oracles depend on:

========================  =========  =====================================
rule                      code       bug class / guarantee
========================  =========  =====================================
unresolvable-except       REPRO001   PR 6: ``except OutOfPages:`` with the
                                     name never imported -- a latent
                                     NameError on a rarely-taken path
raw-wall-clock            REPRO002   PR 8: stray ``time.perf_counter()``
                                     in ``EngineCore.step`` corrupting
                                     phase telemetry; all engine timing
                                     must ride the injectable clock
mutable-default           REPRO003   PR 2: shared mutable dataclass /
                                     keyword defaults
trace-impurity            REPRO004   host-side effects inside jit/
                                     shard_map/Pallas-traced functions
                                     break bit-exactness + trace
                                     neutrality
retrace-hazard            REPRO005   jit shapes derived from per-request
                                     values (prompt length) retrace per
                                     request instead of per config
metric-name-hygiene       REPRO006   registry names must follow the
                                     ``engine_*|kv_*|pressure_*|prefix_*``
                                     + ``_total``/``_seconds`` conventions
                                     and be created at exactly one site
silent-drop               REPRO007   PR 6: bounded deques that evict
                                     without counting (orphan events)
swallowed-exception       REPRO008   bare ``except:`` / broad handlers
                                     that swallow errors in engine code
========================  =========  =====================================
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint.framework import (Finding, ModuleContext, Rule,
                                           dotted_name)

__all__ = ["ALL_RULES", "default_rules", "RULE_INDEX"]

_CLOCK_ATTRS = {"time", "perf_counter", "perf_counter_ns", "monotonic",
                "monotonic_ns", "process_time", "process_time_ns",
                "time_ns"}


# ---------------------------------------------------------------------------
# REPRO001 -- unresolvable-except
# ---------------------------------------------------------------------------

class UnresolvableExcept(Rule):
    name = "unresolvable-except"
    code = "REPRO001"
    description = ("every name in an except clause must resolve to an "
                   "import or binding visible in the module (PR 6 "
                   "shipped a never-imported OutOfPages handler: a "
                   "latent NameError on the rarely-taken path)")
    node_types = (ast.ExceptHandler,)

    def visit(self, node: ast.ExceptHandler, ctx: ModuleContext
              ) -> Iterable[Finding]:
        if node.type is None:
            return                      # bare except: REPRO008's domain
        roots: List[ast.Name] = []
        exprs = (node.type.elts if isinstance(node.type, ast.Tuple)
                 else [node.type])
        for expr in exprs:
            while isinstance(expr, ast.Attribute):
                expr = expr.value
            if isinstance(expr, ast.Name):
                roots.append(expr)
        known = ctx.module_names
        for root in roots:
            if root.id in known:
                continue
            if any(root.id in ctx.scope_locals(s)
                   for s in ctx.enclosing_scopes(node)):
                continue
            yield self.finding(
                ctx, root,
                f"name {root.id!r} in except clause resolves to no "
                f"import or binding in this module -- the handler "
                f"raises NameError the first time the exception "
                f"actually fires")


# ---------------------------------------------------------------------------
# REPRO002 -- raw-wall-clock
# ---------------------------------------------------------------------------

class RawWallClock(Rule):
    name = "raw-wall-clock"
    code = "REPRO002"
    description = ("no direct time.time/perf_counter/monotonic calls in "
                   "engine/launch/training code: route timing through an "
                   "injectable clock attribute (EngineCore._clock) so "
                   "frozen-clock tests cover every timing path (PR 8's "
                   "bug class)")
    paths = ("repro/serving/", "repro/launch/", "repro/training/")
    node_types = (ast.Call,)
    default_config = {"clock_attrs": ("_clock", "clock")}

    def start_module(self, ctx: ModuleContext) -> None:
        imports = ctx.imported_modules()
        self._time_aliases = {local for local, mod in imports.items()
                              if mod == "time"}
        self._from_time = {local for local, mod in imports.items()
                           if mod.startswith("time.")
                           and mod.split(".", 1)[1] in _CLOCK_ATTRS}

    def visit(self, node: ast.Call, ctx: ModuleContext
              ) -> Iterable[Finding]:
        func = node.func
        hit: Optional[str] = None
        if isinstance(func, ast.Attribute) \
                and isinstance(func.value, ast.Name) \
                and func.value.id in self._time_aliases \
                and func.attr in _CLOCK_ATTRS:
            hit = f"{func.value.id}.{func.attr}"
        elif isinstance(func, ast.Name) and func.id in self._from_time:
            hit = func.id
        if hit is not None:
            attrs = ", ".join(f"self.{a}"
                              for a in self.config["clock_attrs"])
            yield self.finding(
                ctx, node,
                f"direct wall-clock read {hit}() -- route timing "
                f"through an injectable clock attribute ({attrs}) so "
                f"frozen-clock tests observe it; bind the clock "
                f"function once (e.g. `clock or time.monotonic`) "
                f"instead of calling the module directly")


# ---------------------------------------------------------------------------
# REPRO003 -- mutable-default
# ---------------------------------------------------------------------------

_MUTABLE_FACTORIES = {"list", "dict", "set", "deque", "defaultdict",
                      "Counter", "OrderedDict", "bytearray"}


def _mutable_default(node: Optional[ast.AST]) -> Optional[str]:
    """Describe the mutable default, or None when the value is safe."""
    if node is None:
        return None
    if isinstance(node, ast.List):
        return "[]" if not node.elts else "a list literal"
    if isinstance(node, ast.Dict):
        return "{}" if not node.keys else "a dict literal"
    if isinstance(node, ast.Set):
        return "a set literal"
    if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp)):
        return "a comprehension"
    if isinstance(node, ast.Call):
        dn = dotted_name(node.func)
        if dn is not None and dn.split(".")[-1] in _MUTABLE_FACTORIES:
            return f"{dn}()"
    return None


def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        dn = dotted_name(target)
        if dn is not None and dn.split(".")[-1] == "dataclass":
            return True
    return False


class MutableDefault(Rule):
    name = "mutable-default"
    code = "REPRO003"
    description = ("keyword defaults and dataclass field defaults must "
                   "not be []/{}/set() or other shared mutable "
                   "instances (PR 2's repo-wide audit): one instance is "
                   "shared by every call/instance")
    node_types = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                  ast.ClassDef)

    def visit(self, node: ast.AST, ctx: ModuleContext
              ) -> Iterable[Finding]:
        if isinstance(node, ast.ClassDef):
            if not _is_dataclass_decorated(node):
                return
            for stmt in node.body:
                value = None
                if isinstance(stmt, ast.AnnAssign):
                    value = stmt.value
                elif isinstance(stmt, ast.Assign):
                    value = stmt.value
                desc = _mutable_default(value)
                if desc:
                    yield self.finding(
                        ctx, stmt,
                        f"dataclass field default is {desc}: one "
                        f"instance is shared by every {node.name} -- "
                        f"use field(default_factory=...)")
            return
        args = node.args
        # defaults align with the *last* len(defaults) positional params
        pos = (list(args.posonlyargs) + list(args.args)
               if hasattr(args, "posonlyargs") else list(args.args))
        for arg, default in zip(pos[len(pos) - len(args.defaults):],
                                args.defaults):
            desc = _mutable_default(default)
            if desc:
                yield self.finding(
                    ctx, default,
                    f"default for parameter {arg.arg!r} is {desc}: "
                    f"one instance is shared across calls -- default "
                    f"to None (or use field(default_factory=...))")
        for arg, default in zip(args.kwonlyargs, args.kw_defaults):
            desc = _mutable_default(default)
            if desc:
                yield self.finding(
                    ctx, default,
                    f"default for parameter {arg.arg!r} is {desc}: "
                    f"one instance is shared across calls -- default "
                    f"to None (or use field(default_factory=...))")


# ---------------------------------------------------------------------------
# REPRO004 -- trace-impurity
# ---------------------------------------------------------------------------

_TRACE_ENTRY_CALLS = {"jit", "pallas_call", "shard_map", "pjit"}
_HOST_RNG_ROOTS = {"random"}


def _is_trace_wrapper(expr: ast.AST) -> bool:
    """True for ``jax.jit`` / ``pl.pallas_call`` / ``shard_map`` (bare
    or behind ``functools.partial``)."""
    if isinstance(expr, ast.Call):
        dn = dotted_name(expr.func)
        if dn is not None and dn.split(".")[-1] == "partial":
            return any(_is_trace_wrapper(a) for a in expr.args)
        return _is_trace_wrapper(expr.func)
    dn = dotted_name(expr)
    return dn is not None and dn.split(".")[-1] in _TRACE_ENTRY_CALLS


class TraceImpurity(Rule):
    name = "trace-impurity"
    code = "REPRO004"
    description = ("functions traced by jax.jit/shard_map/pallas_call "
                   "must be pure functions of their operands: no "
                   "attribute mutation, print, host clocks, host RNG, "
                   "metrics-registry touches, or branching on traced "
                   "array truthiness -- impurity silently breaks the "
                   "bit-exactness and trace-neutrality oracles")
    node_types = (ast.Module,)           # whole-module analysis

    def start_module(self, ctx: ModuleContext) -> None:
        imports = ctx.imported_modules()
        self._time_aliases = {local for local, mod in imports.items()
                              if mod == "time"}
        self._array_roots = {local for local, mod in imports.items()
                             if mod in ("jax.numpy", "jax")}
        self._array_roots |= {"jnp", "jax"}

    # -- entry-point discovery ----------------------------------------
    def _traced_roots(self, ctx: ModuleContext) -> List[ast.AST]:
        roots: List[ast.AST] = []
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
        seeds: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_trace_wrapper(d) for d in node.decorator_list):
                    roots.append(node)
            elif isinstance(node, ast.Call) \
                    and _is_trace_wrapper(node.func):
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        seeds.add(arg.id)
                    elif isinstance(arg, ast.Lambda):
                        roots.append(arg)
            elif isinstance(node, (ast.GeneratorExp, ast.ListComp)):
                # the `tuple(jit(f) for f in (a, b, c))` idiom: every
                # Name in the iterated tuple is a traced function
                tgt = {n.id for gen in node.generators
                       for n in ast.walk(gen.target)
                       if isinstance(n, ast.Name)}
                jitted_target = any(
                    isinstance(c, ast.Call) and _is_trace_wrapper(c.func)
                    and any(isinstance(a, ast.Name) and a.id in tgt
                            for a in c.args)
                    for c in ast.walk(node.elt))
                if jitted_target:
                    for gen in node.generators:
                        if isinstance(gen.iter, (ast.Tuple, ast.List)):
                            seeds.update(e.id for e in gen.iter.elts
                                         if isinstance(e, ast.Name))
        # resolve seeds + transitive module-local callees
        worklist = [d for name in seeds for d in defs.get(name, ())]
        roots.extend(worklist)
        seen = {id(r) for r in roots}
        while worklist:
            fn = worklist.pop()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name):
                    for d in defs.get(node.func.id, ()):
                        if id(d) not in seen:
                            seen.add(id(d))
                            roots.append(d)
                            worklist.append(d)
        return roots

    # -- impurity checks ----------------------------------------------
    def _check_body(self, fn: ast.AST, ctx: ModuleContext
                    ) -> Iterable[Finding]:
        label = getattr(fn, "name", "<lambda>")
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute):
                        dn = dotted_name(t) or f"<expr>.{t.attr}"
                        yield self.finding(
                            ctx, node,
                            f"traced function {label!r} mutates "
                            f"attribute state {dn!r}: host-side "
                            f"effects run at trace time, not per call "
                            f"-- hoist out of the traced region")
            elif isinstance(node, ast.Call):
                dn = dotted_name(node.func)
                if dn is None:
                    continue
                parts = dn.split(".")
                if dn == "print":
                    yield self.finding(
                        ctx, node,
                        f"print() inside traced function {label!r}: "
                        f"runs at trace time only (use jax.debug.print "
                        f"for per-call output)")
                elif parts[0] in self._time_aliases \
                        and parts[-1] in _CLOCK_ATTRS:
                    yield self.finding(
                        ctx, node,
                        f"host clock read {dn}() inside traced "
                        f"function {label!r}: evaluates once at trace "
                        f"time -- timing belongs outside the jit "
                        f"boundary")
                elif (parts[0] in _HOST_RNG_ROOTS
                      or (len(parts) >= 2 and parts[0] in ("np", "numpy")
                          and parts[1] == "random")):
                    yield self.finding(
                        ctx, node,
                        f"host RNG {dn}() inside traced function "
                        f"{label!r}: draws once at trace time and "
                        f"bakes the value into the trace -- use "
                        f"jax.random with an explicit key")
                elif "metrics" in parts[:-1] or parts[-1] == "metrics":
                    yield self.finding(
                        ctx, node,
                        f"metrics-registry touch {dn!r} inside traced "
                        f"function {label!r}: telemetry must stay "
                        f"host-side (trace-neutrality oracle)")
            elif isinstance(node, (ast.If, ast.While)):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Call):
                        sdn = dotted_name(sub.func)
                        if sdn and sdn.split(".")[0] in self._array_roots:
                            yield self.finding(
                                ctx, node,
                                f"traced function {label!r} branches "
                                f"on array truthiness ({sdn}(...)): "
                                f"raises TracerBoolConversionError "
                                f"under jit -- use lax.cond/jnp.where",
                                line=node.lineno)
                            break

    def visit(self, node: ast.Module, ctx: ModuleContext
              ) -> Iterable[Finding]:
        emitted: Set[Tuple[int, str]] = set()
        for fn in self._traced_roots(ctx):
            for f in self._check_body(fn, ctx):
                key = (f.line, f.message)
                if key not in emitted:
                    emitted.add(key)
                    yield f


# ---------------------------------------------------------------------------
# REPRO005 -- retrace-hazard
# ---------------------------------------------------------------------------

class RetraceHazard(Rule):
    name = "retrace-hazard"
    code = "REPRO005"
    description = ("arguments to jitted callables whose shape derives "
                   "from per-request values (prompt length, token "
                   "counts) retrace per request; shapes must be bounded "
                   "by config (chunk size, power-of-two widths)")
    node_types = (ast.Module,)
    default_config = {
        # attribute/variable names that carry per-request token streams
        "request_value_names": ("prompt", "prompt_tokens",
                                "prefill_tokens", "generated", "toks",
                                "tokens", "drafts", "draft"),
        # names bound to jitted callables by project convention (the
        # EngineCore paged-fn tuple) on top of locally-visible
        # `x = jax.jit(...)` bindings
        "extra_jitted_names": ("pre_scan", "pre_chunk", "verify"),
    }

    def _jitted_names(self, ctx: ModuleContext) -> Set[str]:
        names: Set[str] = set(self.config["extra_jitted_names"])
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and _is_trace_wrapper(node.value.func):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
        return names

    def _tainted_names(self, scope: ast.AST) -> Dict[str, str]:
        """local name -> reason, for names assigned from unbounded
        per-request slices/lengths."""
        req_names = set(self.config["request_value_names"])
        tainted: Dict[str, str] = {}
        for node in ast.walk(scope):
            if not isinstance(node, ast.Assign):
                continue
            reason = self._request_shaped(node.value, req_names)
            if reason:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        tainted[t.id] = reason
        return tainted

    @staticmethod
    def _request_shaped(expr: ast.AST, req_names: Set[str]
                        ) -> Optional[str]:
        """A slice or len() over a per-request token stream."""
        for node in ast.walk(expr):
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.slice, ast.Slice):
                base = node.value
                base_name = (base.attr if isinstance(base, ast.Attribute)
                             else base.id if isinstance(base, ast.Name)
                             else None)
                if base_name in req_names:
                    return (f"sliced from per-request "
                            f"{base_name!r}")
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "len" and node.args:
                a = node.args[0]
                a_name = (a.attr if isinstance(a, ast.Attribute)
                          else a.id if isinstance(a, ast.Name) else None)
                if a_name in req_names:
                    return f"len() of per-request {a_name!r}"
        return None

    def visit(self, node: ast.Module, ctx: ModuleContext
              ) -> Iterable[Finding]:
        jitted = self._jitted_names(ctx)
        req_names = set(self.config["request_value_names"])
        for scope in ast.walk(ctx.tree):
            if not isinstance(scope, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            tainted = self._tainted_names(scope)
            for call in ast.walk(scope):
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Name)
                        and call.func.id in jitted):
                    continue
                for arg in call.args:
                    reason = self._request_shaped(arg, req_names)
                    if reason is None:
                        for sub in ast.walk(arg):
                            if isinstance(sub, ast.Name) \
                                    and sub.id in tainted:
                                reason = tainted[sub.id]
                                break
                    if reason:
                        yield self.finding(
                            ctx, call,
                            f"argument to jitted {call.func.id!r} is "
                            f"{reason}: its shape varies per request, "
                            f"so every distinct length compiles a new "
                            f"trace -- pad to a config-bounded width "
                            f"(chunk size / power-of-two rows)")
                        break


# ---------------------------------------------------------------------------
# REPRO006 -- metric-name-hygiene
# ---------------------------------------------------------------------------

_METRIC_CTORS = {"counter": "counter", "gauge": "gauge",
                 "histogram": "histogram"}
_METRIC_USES = {"inc": "counter", "observe": "histogram", "set": "gauge"}
_METRIC_NAME_RE = re.compile(
    r"^(engine|kv|pressure|prefix)_[a-z0-9_]+$")


class MetricNameHygiene(Rule):
    name = "metric-name-hygiene"
    code = "REPRO006"
    description = ("registry metric names must match "
                   "engine_*|kv_*|pressure_*|prefix_* with _total "
                   "(counters) / _seconds-style unit (histograms) "
                   "suffixes, and each name must be created at exactly "
                   "one site")
    paths = ("repro/",)
    node_types = (ast.Call,)
    default_config = {
        "prefixes": ("engine", "kv", "pressure", "prefix"),
        "histogram_suffixes": ("_seconds", "_rate", "_length", "_bytes",
                               "_tokens"),
    }

    def __init__(self, **config):
        super().__init__(**config)
        # literal name -> [(path, line, suppressed)]
        self._creation_sites: Dict[str, List[Tuple[str, int, bool]]] = {}

    def _name_findings(self, kind: str, name_node: ast.AST,
                       ctx: ModuleContext, call: ast.Call
                       ) -> Iterable[Finding]:
        prefixes = self.config["prefixes"]
        hist_sfx = tuple(self.config["histogram_suffixes"])
        if isinstance(name_node, ast.Constant) \
                and isinstance(name_node.value, str):
            name = name_node.value
            head, tail = name, name
        elif isinstance(name_node, ast.JoinedStr) and name_node.values:
            first, last = name_node.values[0], name_node.values[-1]
            if not (isinstance(first, ast.Constant)
                    and isinstance(first.value, str)):
                yield self.finding(
                    ctx, call,
                    f"{kind} name is an f-string with a dynamic "
                    f"prefix: the registry prefix must be a static "
                    f"literal so conventions are checkable")
                return
            head = first.value
            tail = (last.value if isinstance(last, ast.Constant)
                    and isinstance(last.value, str) else None)
            name = None
        else:
            return                      # dynamic: not statically checkable
        if not any(head.startswith(p + "_") for p in prefixes):
            yield self.finding(
                ctx, call,
                f"{kind} name {head!r}... does not start with one of "
                f"the registry prefixes {'|'.join(prefixes)}_")
        if name is not None and not _METRIC_NAME_RE.match(name):
            if any(name.startswith(p + "_") for p in prefixes):
                yield self.finding(
                    ctx, call,
                    f"{kind} name {name!r} must be snake_case "
                    f"[a-z0-9_] after its registry prefix")
        if tail is not None:
            if kind == "counter" and not tail.endswith("_total"):
                yield self.finding(
                    ctx, call,
                    f"counter name {tail!r} must end in _total "
                    f"(Prometheus counter convention)")
            elif kind == "histogram" and not tail.endswith(hist_sfx):
                yield self.finding(
                    ctx, call,
                    f"histogram name {tail!r} must end in a unit "
                    f"suffix ({', '.join(hist_sfx)})")

    def visit(self, node: ast.Call, ctx: ModuleContext
              ) -> Iterable[Finding]:
        if not isinstance(node.func, ast.Attribute) or not node.args:
            return
        attr = node.func.attr
        kind = _METRIC_CTORS.get(attr) or _METRIC_USES.get(attr)
        if kind is None:
            return
        name_node = node.args[0]
        # non-registry .set()/.inc()/... calls (jnp .at[].set, Counter
        # objects) never pass a string first: the literal filter is the
        # discriminator
        if not isinstance(name_node, (ast.Constant, ast.JoinedStr)):
            return
        if isinstance(name_node, ast.Constant) \
                and not isinstance(name_node.value, str):
            return
        yield from self._name_findings(kind, name_node, ctx, node)
        if attr in _METRIC_CTORS and isinstance(name_node, ast.Constant):
            self._creation_sites.setdefault(name_node.value, []).append(
                (ctx.rel, node.lineno,
                 ctx.is_suppressed(self.name, node.lineno)))

    def finalize(self) -> Iterable[Finding]:
        for name, sites in sorted(self._creation_sites.items()):
            if len(sites) <= 1:
                continue
            first = f"{sites[0][0]}:{sites[0][1]}"
            for path, line, suppressed in sites[1:]:
                f = Finding(
                    rule=self.name, code=self.code,
                    severity=self.severity, path=path, line=line, col=1,
                    message=f"metric {name!r} is created at more than "
                            f"one site (first at {first}): one name = "
                            f"one owner, share the metric object "
                            f"instead")
                f.suppressed = suppressed or sites[0][2]
                yield f


# ---------------------------------------------------------------------------
# REPRO007 -- silent-drop
# ---------------------------------------------------------------------------

class SilentDrop(Rule):
    name = "silent-drop"
    code = "REPRO007"
    description = ("bounded deques evict their oldest entry silently on "
                   "append; engine-visible buffers must count evictions "
                   "(PR 6's orphan-event drops) or carry an explicit "
                   "suppression naming the eviction policy")
    paths = ("repro/serving/",)
    node_types = (ast.Call,)

    @staticmethod
    def _class_counts_drops(cls: Optional[ast.ClassDef]) -> bool:
        if cls is None:
            return False
        for node in ast.walk(cls):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and "dropped" in t.attr:
                        return True
        return False

    def visit(self, node: ast.Call, ctx: ModuleContext
              ) -> Iterable[Finding]:
        dn = dotted_name(node.func)
        if dn is None or dn.split(".")[-1] != "deque":
            return
        maxlen = next((kw.value for kw in node.keywords
                       if kw.arg == "maxlen"), None)
        if maxlen is None or (isinstance(maxlen, ast.Constant)
                              and maxlen.value is None):
            return
        if self._class_counts_drops(ctx.enclosing_class(node)):
            return
        yield self.finding(
            ctx, node,
            f"bounded deque(maxlen=...) evicts silently on append: "
            f"count evictions (cf. _CountingDeque / "
            f"stats()['orphans_dropped']) or suppress with the "
            f"eviction policy spelled out")


# ---------------------------------------------------------------------------
# REPRO008 -- swallowed-exception
# ---------------------------------------------------------------------------

_BROAD_EXCEPTIONS = {"Exception", "BaseException"}


class SwallowedException(Rule):
    name = "swallowed-exception"
    code = "REPRO008"
    description = ("no bare except:, and no broad Exception handler "
                   "that swallows silently, in engine code -- a fault "
                   "the engine cannot classify must propagate (the "
                   "quarantine/EngineError taxonomy depends on it)")
    paths = ("repro/serving/",)
    node_types = (ast.ExceptHandler,)

    def visit(self, node: ast.ExceptHandler, ctx: ModuleContext
              ) -> Iterable[Finding]:
        if node.type is None:
            yield self.finding(
                ctx, node,
                "bare except: catches everything including "
                "KeyboardInterrupt/SystemExit -- name the exceptions "
                "the handler can actually handle")
            return
        names = (node.type.elts if isinstance(node.type, ast.Tuple)
                 else [node.type])
        broad = [dotted_name(n) for n in names]
        if not any(b in _BROAD_EXCEPTIONS for b in broad if b):
            return
        for sub in ast.walk(node):
            if isinstance(sub, (ast.Raise, ast.Call, ast.Return,
                                ast.Yield)):
                return                   # observable: re-raise/handle/log
        yield self.finding(
            ctx, node,
            "broad except Exception: handler swallows the error with "
            "no raise/call/return -- engine faults must feed the "
            "quarantine/EngineError taxonomy, not vanish")


ALL_RULES = (UnresolvableExcept, RawWallClock, MutableDefault,
             TraceImpurity, RetraceHazard, MetricNameHygiene, SilentDrop,
             SwallowedException)

RULE_INDEX = {r.name: r for r in ALL_RULES}


def default_rules() -> List[Rule]:
    return [cls() for cls in ALL_RULES]
