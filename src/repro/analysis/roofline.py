"""Three-term roofline analysis from compiled dry-run artifacts.

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(HLO shapes post-SPMD are per-device, so the per-chip division of the
assignment formulas is already applied.)  Also reports MODEL_FLOPS =
6ND / 2ND and its ratio to compiled FLOPs, the dominant term, and a
suggested lever.

Usage:  PYTHONPATH=src python -m repro.analysis.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os
from typing import Optional

# --- hardware constants (v5e-class target; see assignment) ---------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def roofline_from_hlo_text(text: str, *, arch: str, shape_name: str,
                           n_devices: int) -> dict:
    from repro.analysis.hlo import analyze_hlo_text
    from repro.config import SHAPES, get_model_config
    from repro.analysis.flops import model_flops, attention_flops

    cost = analyze_hlo_text(text)
    cfg = get_model_config(arch)
    shape = SHAPES[shape_name]

    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.bytes / HBM_BW
    collective_s = cost.collective_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    mf_per_dev = mf / n_devices
    hlo_flops = max(cost.flops, 1.0)
    af = attention_flops(cfg, shape) / n_devices

    bound_s = max(terms.values())
    # roofline fraction: useful model FLOPs per device per "roofline second"
    roofline_frac = (mf_per_dev / PEAK_FLOPS) / bound_s if bound_s else 0.0

    return {
        "hlo_flops_per_dev": cost.flops,
        "hlo_bytes_per_dev": cost.bytes,
        "collective_bytes_per_dev": cost.collective_bytes,
        "by_collective": cost.by_collective,
        "top_collectives": cost.top_collectives[:8],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_dev": mf_per_dev,
        "attn_flops_per_dev": af,
        "useful_ratio": mf_per_dev / hlo_flops,
        "roofline_fraction": roofline_frac,
    }


def kernel_adjusted_terms(hlo_text: str, *, arch: str, shape_name: str,
                          n_devices: int) -> dict:
    """Substitute the measured reference-attention loop traffic with the
    Pallas kernel's streaming-traffic model (paper T1/T2 on TPU).

    The reference implementation materializes (block_q x block_kv) f32
    score chunks in HBM each scan step; the kernel keeps scores/stats in
    VMEM and streams Q,K,V,O exactly once.  This routine (a) measures the
    attention scan loops' bytes/flops in the compiled artifact (nested
    whiles whose bodies contain exponentials + >=2 dots, including inside
    fusions), (b) replaces their bytes with Q+K+V+O streaming traffic and
    their FLOPs with the causal-skip exact count, (c) leaves everything
    else untouched.
    """
    import re as _re
    from repro.analysis import hlo as H
    from repro.config import SHAPES, get_model_config
    from repro.analysis.flops import attention_flops

    comps, entry = H.parse_hlo(hlo_text)
    memo: dict = {}

    def _whiles(comp):
        out = []
        for inst in comp.instructions:
            if inst.opcode != "while":
                continue
            mb = _re.search(r"body=%?([\w\.\-]+)", inst.attrs)
            mc = _re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
            t = H.infer_trip_count(comps[mc.group(1)], comps) \
                if mc and mc.group(1) in comps else 1
            if mb:
                out.append((mb.group(1), t))
        return out

    def _is_attention_body(name):
        comp = comps.get(name)
        if comp is None:
            return False
        ndots, has_exp = 0, False
        stack = [comp]
        seen = set()
        while stack:
            c = stack.pop()
            if c.name in seen:
                continue
            seen.add(c.name)
            for i in c.instructions:
                if i.opcode == "dot":
                    ndots += 1
                if i.opcode == "exponential":
                    has_exp = True
                for called in i.called():
                    if called in comps:
                        stack.append(comps[called])
        return has_exp and ndots >= 2

    attn_bytes = 0.0
    attn_flops = 0.0
    for body, trips in _whiles(comps[entry]):
        for b2, t2 in _whiles(comps[body]):
            if _is_attention_body(b2):
                c = H.computation_cost(b2, comps, dict(memo))
                attn_bytes += c.bytes * t2 * trips
                attn_flops += c.flops * t2 * trips

    base = roofline_from_hlo_text(hlo_text, arch=arch,
                                  shape_name=shape_name,
                                  n_devices=n_devices)
    cfg = get_model_config(arch)
    shape = SHAPES[shape_name]
    # kernel streaming traffic per device (bf16 in, bf16 out)
    b_loc = max(shape.global_batch // 16, 1)
    sq = shape.seq_len // 16 if shape.kind != "decode" else 1
    layers = sum(1 for k in cfg.blocks() if k not in ("mlstm", "slstm"))
    per_layer = (2 * b_loc * cfg.num_heads * sq * cfg.head_dim * 2        # Q+O
                 + 2 * b_loc * cfg.num_kv_heads * shape.seq_len
                 * cfg.head_dim * 2)                                      # K+V
    kern_bytes = per_layer * layers
    kern_flops = attention_flops(cfg, shape) / n_devices
    adj = dict(base)
    adj["memory_s"] = (base["hlo_bytes_per_dev"] - attn_bytes
                       + kern_bytes) / HBM_BW
    adj["compute_s"] = (base["hlo_flops_per_dev"] - attn_flops
                        + kern_flops) / PEAK_FLOPS
    adj["attn_loop_bytes_measured"] = attn_bytes
    adj["attn_loop_flops_measured"] = attn_flops
    adj["kernel_bytes_model"] = kern_bytes
    terms = {k: adj[k] for k in ("compute_s", "memory_s", "collective_s")}
    adj["dominant"] = max(terms, key=terms.get)
    bound = max(terms.values())
    adj["roofline_fraction"] = (adj["model_flops_per_dev"] / PEAK_FLOPS
                                / bound) if bound else 0.0
    return adj


def lever(rec: dict) -> str:
    """One sentence on what would move the dominant term down."""
    r = rec.get("roofline", rec)
    d = r.get("dominant")
    if d == "collective_s":
        top = r.get("by_collective", {})
        worst = max(top, key=top.get) if top else "all-gather"
        return (f"dominant collective is {worst} "
                f"({top.get(worst, 0)/1e6:.0f} MB/dev): reduce via weight-"
                "stationary sharding, chunked overlap (T3), or smaller "
                "model-axis factor")
    if d == "memory_s":
        return ("HBM-bound: increase arithmetic intensity -- fuse attention "
                "(larger level-1 tiles), widen per-chip batch, or quantize "
                "KV/weights")
    return ("compute-bound: close the useful-FLOPs gap (remat recompute, "
            "causal-skip) and raise MXU utilization via 128-aligned tiles")


def load_records(dir_: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def render_table(recs, *, mesh: Optional[str] = None) -> str:
    rows = []
    hdr = (f"{'cell':52s} {'status':8s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dom':>5s} {'useful':>7s} {'roofl%':>7s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in recs:
        if mesh and r.get("mesh") != mesh:
            continue
        cell = r["cell"]
        if r["status"] != "ok":
            rows.append(f"{cell:52s} {r['status']:8s} "
                        f"{r.get('reason', r.get('error', ''))[:60]}")
            continue
        rf = r.get("roofline")
        if not rf:
            rows.append(f"{cell:52s} ok        (no roofline: "
                        f"{r.get('roofline_error', '?')})")
            continue
        dom = {"compute_s": "comp", "memory_s": "mem",
               "collective_s": "coll"}[rf["dominant"]]
        rows.append(
            f"{cell:52s} {'ok':8s} {rf['compute_s']:9.4f} "
            f"{rf['memory_s']:9.4f} {rf['collective_s']:9.4f} {dom:>5s} "
            f"{rf['useful_ratio']:7.3f} {100*rf['roofline_fraction']:7.2f}")
    return "\n".join(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--reparse", action="store_true",
                    help="re-run the HLO parser on stored .hlo.gz files")
    args = ap.parse_args(argv)
    recs = load_records(args.dir)
    if args.reparse:
        for r in recs:
            if r.get("hlo") and os.path.exists(r["hlo"]):
                with gzip.open(r["hlo"], "rt") as f:
                    text = f.read()
                r["roofline"] = roofline_from_hlo_text(
                    text, arch=r["arch"], shape_name=r["shape"],
                    n_devices=r.get("n_devices", 256))
                with open(os.path.join(
                        args.dir, r["cell"] + ".json"), "w") as f:
                    json.dump(r, f, indent=1)
    print(render_table(recs))
    for r in recs:
        if r.get("status") == "ok" and r.get("roofline"):
            print(f"{r['cell']}: {lever(r)}")


if __name__ == "__main__":
    main()
