"""Analytic parameter / FLOP models used by the roofline analysis.

MODEL_FLOPS follows the assignment convention:
  train:   6 * N * D          (N = params w/o embeddings, D = tokens)
  prefill: 2 * N * D          (forward only)
  decode:  2 * N * D          (D = batch * new tokens)
For MoE, N counts only *active* parameters (shared + top-k experts).
"""
from __future__ import annotations

from repro.config import ModelConfig, ShapeConfig


def _block_params(cfg: ModelConfig, kind: str, active_only: bool) -> int:
    d = cfg.d_model
    h = cfg.q_dim
    kv = cfg.kv_dim
    n = 0
    if kind in ("attn", "attn_local", "moe", "hymba", "hymba_local"):
        n += d * h + 2 * d * kv + h * d          # Wq, Wk, Wv, Wo
        if cfg.qkv_bias:
            n += h + 2 * kv
    if kind in ("hymba", "hymba_local"):
        # mamba branch: in-proj (x,z), conv, dt/B/C projections, out-proj
        dn = cfg.ssm_state_size
        n += d * h * 2                            # in proj (x and gate)
        n += h * cfg.conv_kernel                  # depthwise conv
        n += h * (2 * dn + 1) + h                 # B, C, dt proj + A diag
        n += h * d                                # out proj
    if kind in ("attn", "attn_local", "hymba", "hymba_local"):
        f = cfg.d_ff
        if f:
            mult = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
            n += mult * d * f
    if kind == "moe":
        f = cfg.expert_dff
        mult = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        n += d * cfg.num_experts                  # router
        e = cfg.num_experts_per_tok if active_only else cfg.num_experts
        n += e * mult * d * f
    if kind == "mlstm":
        # up-proj x2, gates (i,f,o from x), qkv projections inside cell, down
        pf = cfg.mlstm_proj_factor
        di = int(d * pf)
        n += 2 * d * di                           # up (cell input + gate)
        n += 3 * di                               # i,f,o gate vectors
        n += 3 * di * di // max(cfg.num_heads, 1) * cfg.num_heads // cfg.num_heads  # placeholder, refined below
        n += di * d                               # down-proj
        # q,k,v projections: di -> di each
        n += 3 * di * di
    if kind == "slstm":
        pf = cfg.mlstm_proj_factor
        di = int(d * pf)
        n += 2 * d * di + di * d
        n += 4 * di * di // max(1, cfg.num_heads)  # recurrent (block-diag per head)
        n += 4 * di                                # gate biases
    # norms
    n += 2 * d
    return n


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model              # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model         # lm head
    for kind in cfg.blocks():
        n += _block_params(cfg, kind, active_only)
    if cfg.is_encoder_decoder:
        for _ in range(cfg.encoder_layers):
            n += _block_params(cfg, "attn", active_only)
            # cross attention in decoder counted once per decoder layer
        n += cfg.num_layers * (2 * cfg.d_model * cfg.q_dim
                               + 2 * cfg.d_model * cfg.kv_dim)
    n += cfg.d_model                              # final norm
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per the assignment formula (useful-compute yardstick)."""
    n_active = param_count(cfg, active_only=True)
    n_embed = cfg.vocab_size * cfg.d_model
    n_body = n_active - n_embed * (1 if cfg.tie_embeddings else 2)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_body * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_body * tokens
    # decode: one new token per sequence
    tokens = shape.global_batch * shape.gen_tokens
    return 2.0 * n_body * tokens


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Exact-attention matmul FLOPs (QK^T + PV), forward pass, all layers.

    Causal halves the score matrix; sliding-window blocks cap the KV extent.
    """
    b = shape.global_batch
    s = shape.seq_len
    total = 0.0
    for kind in cfg.blocks():
        if kind in ("mlstm", "slstm"):
            continue
        w = cfg.window_size if kind.endswith("local") and cfg.window_size else None
        if shape.kind == "decode":
            kvlen = min(w, s) if w else s
            per_q = 2 * 2 * kvlen * cfg.head_dim           # QK^T + PV, q_len=1
            total += b * cfg.num_heads * per_q
        else:
            if w and w < s:
                pairs = s * w - w * (w - 1) // 2 if cfg.causal else s * w * 2
            else:
                pairs = s * (s + 1) // 2 if cfg.causal else s * s
            total += b * cfg.num_heads * 2 * 2 * pairs * cfg.head_dim
    mult = 3.0 if shape.kind == "train" else 1.0           # fwd+bwd
    return total * mult
