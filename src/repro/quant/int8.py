"""Weight-only int8 quantization (paper Appendix D.2: "FastAttention is
orthogonal to ... quantization").

Per-output-channel symmetric int8 for every >=2-D parameter; sub-2-D
leaves (norm scales, biases) stay in their dtype.  Halves weight HBM
traffic (the decode bottleneck per EXPERIMENTS.md §Perf cell 3) at
<0.5% logit drift on the smoke models.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QuantizedTensor(NamedTuple):
    q: jax.Array           # int8
    scale: jax.Array       # f32, per output channel (last dim)


def quantize_tensor(w: jax.Array) -> QuantizedTensor:
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=tuple(range(w.ndim - 1)),
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32))


def dequantize_tensor(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (qt.q.astype(jnp.float32) * qt.scale).astype(dtype)


def _should_quantize(x) -> bool:
    return (hasattr(x, "ndim") and x.ndim >= 2
            and jnp.issubdtype(x.dtype, jnp.floating))


def quantize_tree(params: Any) -> Any:
    """Quantize every matrix leaf; returns a tree with QuantizedTensor
    leaves where quantized, original leaves elsewhere."""
    return jax.tree.map(
        lambda x: quantize_tensor(x) if _should_quantize(x) else x, params)


def dequantize_tree(qparams: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda x: dequantize_tensor(x, dtype)
        if isinstance(x, QuantizedTensor) else x,
        qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def quantized_size_bytes(qparams: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(qparams):
        total += leaf.size * leaf.dtype.itemsize
    return total
