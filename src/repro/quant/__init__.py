from repro.quant.int8 import (dequantize_tree, quantize_tree,  # noqa: F401
                              quantized_size_bytes)
