"""Jit'd wrapper for the flash-decode kernel (inference only, no vjp)."""
from __future__ import annotations

from typing import Optional

from repro.kernels.flash_decode.kernel import flash_decode_fwd


def flash_decode(q, k_cache, v_cache, kv_len, *,
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 scale: Optional[float] = None,
                 block_kv: int = 512,
                 interpret: bool = False):
    """Decode attention: q (B, Hq, D) against (B, Hkv, S, D) caches."""
    return flash_decode_fwd(
        q, k_cache, v_cache, kv_len, window=window, softcap=softcap,
        scale=scale, block_kv=block_kv, interpret=interpret)
