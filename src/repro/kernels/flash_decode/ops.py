"""Jit'd wrappers for the flash-decode kernels (inference only, no vjp)."""
from __future__ import annotations

from typing import Optional

from repro.kernels.flash_decode.kernel import (flash_decode_fwd,
                                               paged_flash_decode_fwd)


def flash_decode(q, k_cache, v_cache, kv_len, *,
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 scale: Optional[float] = None,
                 block_kv: int = 512,
                 interpret: bool = False):
    """Decode attention: q (B, Hq, D) against (B, Hkv, S, D) caches."""
    return flash_decode_fwd(
        q, k_cache, v_cache, kv_len, window=window, softcap=softcap,
        scale=scale, block_kv=block_kv, interpret=interpret)


def paged_flash_decode(q, k_pages, v_pages, page_table, kv_len, *,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       scale: Optional[float] = None,
                       interpret: bool = False):
    """Decode attention over a paged cache: q (B, Hq, D), pages
    (Hkv, P, page_size, D), page_table (B, n_kv) int32."""
    return paged_flash_decode_fwd(
        q, k_pages, v_pages, page_table, kv_len, window=window,
        softcap=softcap, scale=scale, interpret=interpret)
