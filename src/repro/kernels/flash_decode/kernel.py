"""Flash-decode kernel: one new token against a long KV cache.

Applies the paper's level-1 tiling to the decode phase: the KV cache is
streamed through VMEM in ``block_kv`` macro-blocks (double-buffered by the
Pallas pipeline) and reduced with online softmax.  GQA query heads of one
KV group are folded into the sub-lane dimension so the per-block matmul is
(G x D) @ (D x block_kv) -- MXU-shaped instead of vector-shaped.

Per-sequence cache lengths arrive via scalar prefetch; the KV index map
clamps out-of-range blocks onto the last valid block so they are neither
fetched nor computed (grid-level tiling-mask skip, T2 at decode time).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            window: Optional[int], softcap: Optional[float], scale: float,
            block_kv: int, n_kv: int, g_pad: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    kv_len = len_ref[b]
    last_valid = jnp.maximum(kv_len - 1, 0) // block_kv
    first_valid = 0
    if window is not None:
        first_valid = jnp.maximum(kv_len - window, 0) // block_kv

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when((ki >= first_valid) & (ki <= last_valid))
    def _compute():
        q = q_ref[0, 0]                                   # (g_pad, D)
        k = k_ref[0, 0]                                   # (block_kv, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (g_pad, block_kv)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (g_pad, block_kv), 1)
        valid = pos < kv_len
        if window is not None:
            valid = valid & (pos >= kv_len - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.broadcast_to(jnp.max(s, axis=1, keepdims=True),
                                 m_prev.shape)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), m_prev.shape)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "block_kv", "interpret"))
def flash_decode_fwd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None,
                     block_kv: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); caches: (B, Hkv, S, D); kv_len: (B,) int32.

    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, skv = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    g_pad = max(8, g)
    scale = scale if scale is not None else d ** -0.5

    block_kv = min(block_kv, skv)
    skv_p = (skv + block_kv - 1) // block_kv * block_kv
    if skv_p != skv:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    n_kv = skv_p // block_kv

    # fold GQA groups: (B, Hq, D) -> (B, Hkv, g_pad, D)
    qg = q.reshape(b, hkv, g, d)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))

    def q_map(bi, hi, ki, len_ref):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, len_ref):
        last = jnp.maximum(len_ref[bi] - 1, 0) // block_kv
        ki = jnp.minimum(ki, last)
        if window is not None:
            first = jnp.maximum(len_ref[bi] - window, 0) // block_kv
            ki = jnp.maximum(ki, first)
        return (bi, hi, ki, 0)

    kernel = functools.partial(
        _kernel, window=window, softcap=softcap, scale=scale,
        block_kv=block_kv, n_kv=n_kv, g_pad=g_pad)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hkv, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, g_pad, d), q_map),
                pl.BlockSpec((1, 1, block_kv, d), kv_map),
                pl.BlockSpec((1, 1, block_kv, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g_pad, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((g_pad, d), jnp.float32),
                pltpu.VMEM((g_pad, LANES), jnp.float32),
                pltpu.VMEM((g_pad, LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g_pad, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out[:, :, :g].reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Paged variant: KV lives in a global page pool, indexed per sequence
# ---------------------------------------------------------------------------

def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, **kw):
    # Page indirection happens entirely in the BlockSpec index map; once a
    # page is resident in VMEM the reduction is identical to the dense case.
    del pt_ref
    _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, **kw)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "interpret"))
def paged_flash_decode_fwd(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           kv_len: jax.Array, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """Flash decode over a paged KV cache.

    q: (B, Hq, D); pages: (Hkv, P, page_size, D) global pools shared by
    every sequence; page_table: (B, n_kv) int32 mapping logical KV block
    ``ki`` of sequence ``b`` to its physical page; kv_len: (B,) int32.

    The page size doubles as the kernel's ``block_kv``: the KV BlockSpec
    index map resolves the logical block through the scalar-prefetched
    page table, so the Pallas pipeline DMAs exactly the pages a sequence
    owns (clamped to the valid [first, last] logical range -- out-of-range
    grid steps re-fetch an owned page and are masked out, never touching
    pages of other sequences).  Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, _, block_kv, _ = k_pages.shape
    n_kv = page_table.shape[1]
    assert hq % hkv == 0
    g = hq // hkv
    g_pad = max(8, g)
    scale = scale if scale is not None else d ** -0.5

    qg = q.reshape(b, hkv, g, d)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))

    def q_map(bi, hi, ki, pt_ref, len_ref):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, pt_ref, len_ref):
        last = jnp.maximum(len_ref[bi] - 1, 0) // block_kv
        ki = jnp.minimum(ki, last)
        if window is not None:
            first = jnp.maximum(len_ref[bi] - window, 0) // block_kv
            ki = jnp.maximum(ki, first)
        return (hi, pt_ref[bi, ki], 0, 0)

    kernel = functools.partial(
        _paged_kernel, window=window, softcap=softcap, scale=scale,
        block_kv=block_kv, n_kv=n_kv, g_pad=g_pad)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, g_pad, d), q_map),
                pl.BlockSpec((1, 1, block_kv, d), kv_map),
                pl.BlockSpec((1, 1, block_kv, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g_pad, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((g_pad, d), jnp.float32),
                pltpu.VMEM((g_pad, LANES), jnp.float32),
                pltpu.VMEM((g_pad, LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g_pad, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out[:, :, :g].reshape(b, hq, d)
