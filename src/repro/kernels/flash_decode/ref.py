"""Oracle for flash_decode: re-exports the fastattn decode reference."""
from repro.kernels.fastattn.ref import decode_reference  # noqa: F401
