"""Oracles for flash_decode: dense re-export + paged-gather reference.

``paged_gather`` materialises the dense (B, Hkv, S, D) view of a paged
pool; ``paged_decode_reference`` chains it with the dense decode oracle so
paged kernels have an f32-softmax reference on any backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.fastattn.ref import decode_reference  # noqa: F401


def paged_gather(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """pages: (Hkv, P, page_size, D); page_table: (B, n_kv) int32.

    Returns the dense per-sequence view (B, Hkv, n_kv * page_size, D).
    """
    g = pages[:, page_table]                   # (Hkv, B, n_kv, ps, D)
    hkv, b, n_kv, ps, d = g.shape
    return g.transpose(1, 0, 2, 3, 4).reshape(b, hkv, n_kv * ps, d)


def paged_decode_reference(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           kv_len: jax.Array, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, 1, D) against paged pools.  Returns (B, Hq, 1, D)."""
    k = paged_gather(k_pages, page_table)
    v = paged_gather(v_pages, page_table)
    return decode_reference(q, k, v, kv_len, window=window, softcap=softcap,
                            scale=scale)
