"""Oracles for flash_decode: dense re-export + paged-gather reference.

``paged_gather`` materialises the dense (B, Hkv, S, D) view of a paged
pool; ``paged_decode_reference`` chains it with the dense decode oracle so
paged kernels have an f32-softmax reference on any backend.
``paged_prefill_reference`` is the chunked-prefill analogue: gather +
online-softmax flash with runtime per-sequence query offsets (the
jittable CPU path of the paged prefill kernel).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.fastattn.ref import (decode_reference,  # noqa: F401
                                        flash_reference_with_lse)


def paged_gather(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """pages: (Hkv, P, page_size, D); page_table: (B, n_kv) int32.

    Returns the dense per-sequence view (B, Hkv, n_kv * page_size, D).
    """
    g = pages[:, page_table]                   # (Hkv, B, n_kv, ps, D)
    hkv, b, n_kv, ps, d = g.shape
    return g.transpose(1, 0, 2, 3, 4).reshape(b, hkv, n_kv * ps, d)


def paged_decode_reference(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           kv_len: jax.Array, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, 1, D) against paged pools.  Returns (B, Hq, 1, D)."""
    k = paged_gather(k_pages, page_table)
    v = paged_gather(v_pages, page_table)
    return decode_reference(q, k, v, kv_len, window=window, softcap=softcap,
                            scale=scale)


def paged_prefill_reference(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, page_table: jax.Array,
                            pos_start: jax.Array, kv_len: jax.Array, *,
                            window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            scale: Optional[float] = None,
                            block_kv: int = 512) -> jax.Array:
    """Chunked-prefill attention oracle over paged pools.

    q: (B, Hq, Sq, D) chunk queries; pos_start: (B,) int32 global position
    of each sequence's chunk start; kv_len: (B,) int32 valid KV length.
    Both offsets are runtime values, so a single trace serves every chunk
    of every prompt (the gathered view has the fixed page-table width).
    Returns (B, Hq, Sq, D); rows past the valid chunk length are garbage
    and must be ignored by the caller.
    """
    k = paged_gather(k_pages, page_table)
    v = paged_gather(v_pages, page_table)
    out, _ = flash_reference_with_lse(
        q, k, v, causal=True, window=window, softcap=softcap, scale=scale,
        q_offset=pos_start, kv_len=kv_len, block_kv=block_kv)
    return out
