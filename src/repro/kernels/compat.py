"""Version shims for the Pallas TPU API surface.

The kernels target the current Pallas naming; older jaxlibs in CPU-only CI
containers still expose the ``TPU``-prefixed aliases.  Centralising the
lookup keeps every kernel importable (and runnable under ``interpret=True``)
across the jax versions we see in practice.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams in jax 0.4.46
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
